"""CheckpointManager — training state as layered, content-addressed images.

A training checkpoint is an *image* whose layers mirror a Dockerfile:

    FROM <arch>                      (config layer, empty)
    COPY params/embed                (content layer)
    COPY params/blocks               (content layer — the big one)
    COPY params/head                 (content layer)
    RUN  adamw_init                  (content layer: m/v/master, derives
                                      from the params layers)
    ENV  step=<n>                    (config layer)

Two save modes, benchmarked against each other (the paper's comparison):

* ``save_full``  — Docker-faithful baseline: `build_image` with DLC cache
  rules; any param change re-serializes + re-hashes whole layers and falls
  through to everything below.
* ``save_incremental`` — the paper's code-injection method: per-chunk diff
  (optionally pre-filtered by on-device fingerprints), clone-before-inject,
  chunk-level writes, checksum re-key. Cost O(changed bytes), not O(state).

The fingerprint-mode save is a fused device+host pipeline (the repo's perf
tentpole; benchmarks/run.py::bench_incremental_save records it):

  1. device   — ``fingerprint_tree_packed``: every leaf's uint32 lanes are
     packed into ONE buffer and fingerprinted in a single dispatch
     (``packed_fingerprints=False`` keeps the per-leaf dispatch baseline);
     only the (total_chunks, 2) table crosses D2H (``BuildReport.bytes_d2h``).
  2. diff     — fingerprint compare prefilters unchanged chunks
     (``BuildReport.chunks_prefiltered``); only changed chunk *ranges* are
     serialized (``tensor_chunk_bytes``) and SHA-256'd on the shared hash
     pool. Leaves stay device-resident until a range is actually touched.
  3. store    — all changed layers go through ONE multi-layer injection
     (``core.inject.inject_image_multi``): clone-before-inject per layer,
     a single downstream re-key walk and a single manifest commit per
     save, with per-chunk fsyncs deferred to that commit point and issued
     as one concurrent batch. ``BuildReport.per_layer`` attributes
     chunks/bytes/re-keys to each layer of the checkpoint image.

Async: serialization of the *diff payload* happens on the caller thread
(cheap: only changed chunks), blob/manifest writes go to a background
executor; `wait()` joins. Atomicity: the image manifest rename is the
commit point (see core.store), so a crash mid-save leaves the previous
checkpoint intact — tests/test_ft.py kills a save mid-flight to prove it.
"""
from __future__ import annotations

import re
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core import (BuildReport, Instruction, LayerStore, PassiveRegistry,
                    RelayNode, diff_image, fingerprint_tree,
                    fingerprint_tree_packed, inject_image_multi, push_delta,
                    replicate_fanout)
from ..ft.faults import CrashInjected


def flatten_tree(tree, prefix="") -> Dict[str, np.ndarray]:
    """pytree -> flat {path: array} with '/'-joined keys.

    Leaves are kept AS-IS (device arrays stay on device): forcing
    ``np.asarray`` here would pull the entire checkpoint over the host link
    on every save — exactly the O(state) transfer the fingerprint prefilter
    exists to avoid. Serialization (chunker.tensor_to_bytes /
    tensor_chunk_bytes) converts lazily, and with fingerprints enabled only
    the *changed* tensors' bytes ever cross D2H.
    """
    out: Dict[str, np.ndarray] = {}

    def walk(t, path):
        if isinstance(t, dict):
            for k2 in sorted(t.keys()):
                walk(t[k2], f"{path}/{k2}" if path else k2)
        elif hasattr(t, "dtype") and hasattr(t, "shape"):
            out[path] = t
        else:
            out[path] = np.asarray(t)

    walk(tree, prefix)
    return out


def unflatten_tree(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


# Step-tag helpers, shared by CheckpointManager and serve.CheckpointFollower
# so the trainer and a serving replica can never disagree about the tag
# format or the retention semantics.

_STEP_TAG = re.compile(r"step-(\d+)")


def step_of_tag(tag: str) -> Optional[int]:
    """The step number of a canonical step tag, None for anything else.
    User-pushed tags (``best``, ``release``, even ``step-final``) are not
    step tags: they must never crash step parsing and never participate in
    retention — skipping them here is what keeps ``latest_step`` and
    ``prune_steps`` safe in an image with mixed tags. Canonical means the
    tag round-trips through ``CheckpointManager.tag_of`` — every caller of
    ``latest_step`` reconstructs the tag as ``step-{n:08d}``, so a
    hand-pushed ``step-9`` must count as a user tag too (it would
    reconstruct to a tag that doesn't exist)."""
    m = _STEP_TAG.fullmatch(tag)
    if not m:
        return None
    n = int(m.group(1))
    return n if tag == f"step-{n:08d}" else None


def latest_step(store: LayerStore, image: str,
                fresh: bool = False) -> Optional[int]:
    """Newest step number among an image's ``step-<digits>`` tags; tags
    that aren't step tags are skipped, not parsed. ``fresh`` bypasses the
    store's tag cache (needed when another process commits the tags)."""
    return max((s for s in (step_of_tag(t)
                            for t in store.list_tags(image, fresh=fresh))
                if s is not None), default=None)


def prune_steps(store: LayerStore, image: str, keep: int) -> bool:
    """Retention + reclamation: drop step tags beyond the ``keep`` newest,
    then mark-and-sweep the store so their exclusive blobs/layers are
    actually deleted (unbounded disk growth otherwise). Returns whether
    anything was removed. ``keep<=0`` keeps everything.

    Ordering is NUMERIC on the parsed step, and non-canonical tags
    (``best``, ``release``, ``step-final``, a hand-pushed ``step-9``) are
    never candidates — retention must not be able to delete a user's
    pin, and must never mistake one for the newest checkpoint.

    Tags under an active retention LEASE (a relay pinning the base a
    lagging child's delta still negotiates against — see
    ``LayerStore.acquire_lease``) are skipped, not deleted: retention on
    a relay must never pull the base out from under an in-flight child
    pull. The skip is tag-granular and temporary — once the child commits
    (release) or dies (TTL expiry), the next prune cycle reclaims it."""
    if keep <= 0:
        return False
    steps = sorted((s, t) for t in store.list_tags(image)
                   if (s := step_of_tag(t)) is not None)
    removed = False
    for _, t in steps[:-keep]:
        # remove_image refuses leased tags on its own; checking here too
        # keeps the gc() decision honest (a fully-leased prune is a no-op)
        if store.leased(image, t):
            continue
        removed = store.remove_image(image, t) or removed
    if removed:
        store.gc()
    return removed


@dataclass
class CheckpointPolicy:
    every_steps: int = 100
    keep: int = 3
    incremental: bool = True          # the paper's technique (vs baseline)
    use_fingerprints: bool = False    # on-device change detection
    packed_fingerprints: bool = True  # ONE dispatch for the whole tree
                                      # (False = per-leaf dispatch baseline)
    async_write: bool = True
    chunk_bytes: int = 1 << 20
    durability: str = "batch"         # the store-wide default: per-chunk
                                      # fsyncs defer to one concurrent
                                      # flush at the manifest commit point
                                      # ("full" = seed per-write fsyncs)
    # passive-registry publish-on-save policy (active only when the
    # manager is given a ``registry=``): after each save, advertise a
    # full head bundle plus one squashed bundle per span, where span k
    # reaches back k COMMITTED step tags (not k raw steps — saves land
    # every ``every_steps`` and retention prunes, so committed tags are
    # the only honest distance metric). (1, 4, 8) keeps a fresh edge one
    # tiny hop from head while an edge that slept through 8 saves still
    # finds a single squashed bundle instead of a full pull.
    publish_spans: Tuple[int, ...] = (1, 4, 8)


class CheckpointManager:
    """See module docstring. Multi-tenant form: ``image=`` names this
    manager's image (default ``"ckpt"``), and several managers may share
    ONE ``LayerStore`` (pass ``store=``; ``root`` is then ignored) — the
    cross-image blob universe, where tenant checkpoints dedup against each
    other and against a shared base. ``base_image=("name", "tag")`` forks
    this manager's FIRST save from another image in the same store: the
    build runs with that image as its DLC cache parent, so unchanged
    layers reuse the base's layer ids outright — which is exactly what
    lets ``replicate``/``replicate_fanout`` later ship only the adapter
    delta to replicas that already hold the base image. Retention
    (``prune_steps`` + the store-wide ``gc()``) is per image but
    cross-image safe: pruning one tenant never sweeps blobs a sibling
    image still reaches."""

    IMAGE = "ckpt"

    def __init__(self, root: str, arch: str,
                 policy: Optional[CheckpointPolicy] = None,
                 image: Optional[str] = None,
                 base_image: Optional[Tuple[str, str]] = None,
                 store: Optional[LayerStore] = None,
                 registry=None):
        self.policy = policy or CheckpointPolicy()
        # a shared store keeps ITS chunking/durability: tenants of one
        # universe must agree on chunk geometry or dedup silently dies
        self.store = store if store is not None else LayerStore(
            root, chunk_bytes=self.policy.chunk_bytes,
            durability=self.policy.durability)
        self.image = image or self.IMAGE
        self.base_image = base_image
        self.arch = arch
        # passive bundle registry to publish into after each save (a
        # PassiveRegistry, or a local directory path). Publishing is
        # best-effort: see _publish.
        self.registry = registry if registry is None or \
            isinstance(registry, PassiveRegistry) \
            else PassiveRegistry(str(registry))
        if self.registry is not None:
            self.registry.attach_gc(self.store, self.image)
        self.last_publish = None
        self.last_publish_error: Optional[str] = None
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._last_fps: Dict[str, np.ndarray] = {}
        self.last_report: Optional[BuildReport] = None

    # ------------------------------------------------------------ layout
    def _instructions(self) -> List[Instruction]:
        return [
            Instruction("FROM", self.arch, "config"),
            Instruction("COPY", "params/embed", "content"),
            Instruction("COPY", "params/blocks", "content"),
            Instruction("COPY", "params/head", "content"),
            Instruction("RUN", "opt_state", "content",
                        derives_from=[]),   # values evolve, not re-derived
            Instruction("ENV", "meta", "config"),
        ]

    def _payloads(self, params, opt_state, step: int
                  ) -> Dict[str, Dict[str, np.ndarray]]:
        flat = flatten_tree(params, "params")
        embed = {k: v for k, v in flat.items()
                 if k.startswith("params/embed")}
        blocks = {k: v for k, v in flat.items()
                  if k.startswith("params/blocks")}
        head = {k: v for k, v in flat.items()
                if not k.startswith(("params/embed", "params/blocks"))}
        opt = flatten_tree(opt_state, "opt")
        opt["opt/__step__"] = np.asarray([step], np.int32)
        return {"params/embed": embed, "params/blocks": blocks,
                "params/head": head, "opt_state": opt}

    # -------------------------------------------------------------- save
    def tag_of(self, step: int) -> str:
        return f"step-{step:08d}"

    def latest_step(self) -> Optional[int]:
        # list_tags is cached in the store (invalidated at the manifest
        # commit / image removal), so polling this every save is free.
        return latest_step(self.store, self.image)

    def wait(self) -> Optional[BuildReport]:
        if self._pending is not None:
            self.last_report = self._pending.result()
            self._pending = None
        return self.last_report

    def save(self, step: int, params, opt_state) -> BuildReport:
        """Dispatches to full or incremental save per policy."""
        self.wait()
        payloads = self._payloads(params, opt_state, step)
        if self.policy.incremental and self.latest_step() is not None:
            fn = self._save_incremental
        else:
            fn = self._save_full
        if self.policy.async_write:
            self._pending = self._pool.submit(fn, step, payloads)
            return BuildReport()        # async: report available at wait()
        report = fn(step, payloads)
        self.last_report = report
        return report

    def _compute_fps(self, payloads: Dict[str, Dict[str, np.ndarray]],
                     stats: dict) -> Dict[str, np.ndarray]:
        """Fingerprint every tensor of the checkpoint. Packed mode issues
        ONE fused device dispatch + one D2H transfer for the whole tree
        (core.fingerprint.fingerprint_tree_packed); per-leaf mode is the
        dispatch-per-tensor baseline kept for benchmarking."""
        union: Dict[str, np.ndarray] = {}
        for tree in payloads.values():
            union.update(tree)
        if self.policy.packed_fingerprints:
            return fingerprint_tree_packed(union, self.policy.chunk_bytes,
                                           stats=stats)
        fps = fingerprint_tree(union, self.policy.chunk_bytes)
        stats["bytes_d2h"] = stats.get("bytes_d2h", 0) + \
            sum(v.nbytes for v in fps.values())
        stats["device_dispatches"] = stats.get("device_dispatches", 0) + \
            len(fps)
        return fps

    def _save_full(self, step: int,
                   payloads: Dict[str, Dict[str, np.ndarray]],
                   fps: Optional[Dict[str, np.ndarray]] = None
                   ) -> BuildReport:
        prev = self.latest_step()
        parent = (self.image, self.tag_of(prev)) if prev is not None \
            else self.base_image
        providers = {k: (lambda p=v: p) for k, v in payloads.items()}
        ins = self._instructions()
        ins[-1] = Instruction("ENV", f"meta step={step}", "config")
        _, _, report = self.store.build_image(
            self.image, self.tag_of(step), ins, providers, parent=parent,
            arch=self.arch)
        if self.policy.use_fingerprints:
            # bootstrap the change detector for the NEXT incremental save
            stats: dict = {}
            self._last_fps = fps if fps is not None else \
                self._compute_fps(payloads, stats)
            report.bytes_d2h += stats.get("bytes_d2h", 0)
        self._gc()
        self._publish()
        return report

    def _save_incremental(self, step: int,
                          payloads: Dict[str, Dict[str, np.ndarray]]
                          ) -> BuildReport:
        """The paper's injection path (C1-C4) as ONE multi-layer batch: a
        save touching embed+blocks+head pays a single clone+re-key walk and
        a single manifest commit (durability="batch" defers every blob
        fsync of the batch to that commit point), with per-layer cost
        attribution in ``BuildReport.per_layer``."""
        prev = self.latest_step()
        manifest, _ = self.store.read_image(self.image, self.tag_of(prev))
        stats: dict = {}
        new_fps: Dict[str, np.ndarray] = {}
        if self.policy.use_fingerprints:
            new_fps = self._compute_fps(payloads, stats)
        layers = [self.store.read_layer(lid) for lid in manifest.layer_ids]
        if self.policy.use_fingerprints:
            diffs = diff_image(layers, payloads,
                               old_fps=self._last_fps, new_fps=new_fps)
        else:
            diffs = diff_image(layers, payloads)
        try:
            # one batched transaction under the POLICY's durability mode
            # (batch = one deferred fsync flush at the manifest commit)
            _, _, report = inject_image_multi(
                self.store, self.image, self.tag_of(prev),
                self.tag_of(step), diffs,
                providers={k: (lambda p=v: p) for k, v in payloads.items()},
                durability=self.policy.durability)
        except CrashInjected:
            raise           # simulated SIGKILL: the process is gone, it
            # cannot fall back to a full rebuild "after" dying
        except Exception:  # noqa: BLE001
            # structure changed ("compiled" case) -> rebuild fall-back
            report = self._save_full(step, payloads,
                                     fps=new_fps if new_fps else None)
        report.bytes_d2h += stats.get("bytes_d2h", 0)
        if self.policy.use_fingerprints:
            self._last_fps = new_fps or self._last_fps
        self._gc()
        self._publish()
        return report

    def _gc(self) -> None:
        """Retention (see ``prune_steps``). Runs post-commit on the save
        thread, so no batch transaction is open; LayerStore.gc additionally
        refuses to sweep anything still dirty in an open one."""
        prune_steps(self.store, self.image, self.policy.keep)

    def _publish(self) -> None:
        """Advertise the just-committed head in the passive bundle
        registry (``policy.publish_spans``): a full bundle plus one
        squashed bundle per span back over the committed step tags.
        Best-effort by contract — a dead object store must never fail a
        save, so every error is swallowed into ``last_publish_error``
        and the next save's publish retries (the index stays
        stale-but-consistent in the meantime, which followers already
        treat as a fall-back signal)."""
        if self.registry is None:
            return
        try:
            steps = sorted(s for t in self.store.list_tags(self.image)
                           if (s := step_of_tag(t)) is not None)
            if not steps:
                return
            froms = [self.tag_of(steps[-1 - span])
                     for span in self.policy.publish_spans
                     if span < len(steps)]
            self.last_publish = self.registry.publish_image(
                self.store, self.image, self.tag_of(steps[-1]),
                from_tags=froms)
            self.last_publish_error = None
        except CrashInjected:
            raise           # the saver process dying is not "a dead
            # object store" — best-effort must not swallow the crash
        except Exception as e:  # noqa: BLE001
            self.last_publish_error = f"{type(e).__name__}: {e}"

    # --------------------------------------------------------- replication
    def replicate(self, remote=None, step: Optional[int] = None,
                  relay=None, source: Optional[str] = None):
        """Ship a checkpoint to serving/registry stores as a DELTA: one
        have-set negotiation + only the chunks a remote is missing cross
        the wire. After an incremental save this is O(changed bytes) —
        call it at the save cadence to keep serving replicas hot.

        ``remote`` is a LayerStore or filesystem path (-> ``push_delta``,
        returns PushStats, failures raise), or a list/tuple of them (->
        ``replicate_fanout``, returns FanoutStats: ONE negotiation round +
        one source read pass for the whole fleet, per-replica failures
        isolated so one sick replica never blocks the rest).

        ``relay`` adds multi-hop tiers (trainer -> M relays -> N edge
        followers each): a dict ``{relay_store_or_path: [children...]}``,
        or a sequence of ``RelayNode``s / ``(store_or_path, children)``
        pairs; children may themselves be any of those shapes, so tiers
        nest. Relays and plain remotes ride the SAME fan-out (one
        negotiation round, one source read pass); each relay re-fans its
        pull to its children — streaming from the in-flight pull with
        ``source="inflight"``, after its own commit with "commit", or each
        node's configured mode when None. Returns FanoutStats whose
        ``replicas[i].children`` nests each relay's downstream outcome."""
        self.wait()
        if remote is None and relay is None:
            raise ValueError("replicate() needs a destination: pass "
                             "remote=, relay=, or both")
        step = step if step is not None else self.latest_step()
        if step is None:
            return None

        def as_store(r):
            # RelayNodes pass through untouched (replicate_fanout accepts
            # receivers directly), so a relay may ride in a remote list
            if isinstance(r, (LayerStore, RelayNode)):
                return r
            return LayerStore(str(r), chunk_bytes=self.policy.chunk_bytes)

        def as_relays(spec):
            # dict {store: children} | sequence of RelayNode /
            # (store, children) pairs — children recurse through the same
            # shapes, so tiers nest in any of them
            out = []
            for item in (spec.items() if isinstance(spec, dict) else spec):
                if isinstance(item, RelayNode):
                    out.append(item)
                    continue
                store, children = item
                if isinstance(children, (str, bytes)):
                    # would be iterated per CHARACTER into junk stores
                    raise TypeError("relay children must be a sequence, "
                                    f"not a bare path: {children!r}")
                kids = []
                for c in children:
                    if isinstance(c, dict):
                        kids.extend(as_relays(c))
                    elif isinstance(c, (tuple, RelayNode)):
                        kids.extend(as_relays([c]))
                    else:
                        kids.append(as_store(c))
                out.append(RelayNode(as_store(store), children=kids))
            return out

        if relay is not None:
            relays = as_relays(relay)
            plain = [] if remote is None else (
                list(remote) if isinstance(remote, (list, tuple)) else [remote])
            return replicate_fanout(
                self.store, [as_store(r) for r in plain] + relays,
                self.image, self.tag_of(step), source=source)
        if isinstance(remote, (list, tuple)):
            # source re-modes RelayNodes the caller put in the list; with
            # none present it would be a silent no-op, so reject it the
            # same way the single-remote branch does
            if source is not None and \
                    not any(isinstance(r, RelayNode) for r in remote):
                raise ValueError("source= only applies to relay "
                                 "topologies; no relay in the remote list")
            return replicate_fanout(self.store, [as_store(r) for r in remote],
                                    self.image, self.tag_of(step),
                                    source=source)
        if source is not None and not isinstance(remote, RelayNode):
            raise ValueError("source= only applies to relay topologies; a "
                             "plain remote has no re-fan to mode")
        if isinstance(remote, RelayNode):
            fan = replicate_fanout(self.store, [remote], self.image,
                                   self.tag_of(step), source=source)
            rep = fan.replicas[0]
            if rep.exception is not None:
                raise rep.exception
            return fan
        return push_delta(self.store, as_store(remote), self.image,
                          self.tag_of(step))

    # ------------------------------------------------------------ restore
    def restore(self, step: Optional[int] = None
                ) -> Optional[Tuple[Any, Any, int]]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        flat = self.store.load_image_payload(self.image, self.tag_of(step))
        opt_flat = {k[len("opt/"):]: v for k, v in flat.items()
                    if k.startswith("opt/")}
        saved_step = int(opt_flat.pop("__step__")[0])
        params_flat = {k[len("params/"):]: v for k, v in flat.items()
                       if k.startswith("params/")}
        return (unflatten_tree(params_flat), unflatten_tree(opt_flat),
                saved_step)
