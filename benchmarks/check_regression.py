"""CI benchmark-regression gate.

    PYTHONPATH=src python -m benchmarks.run --quick
    PYTHONPATH=src python -m benchmarks.check_regression [--threshold 1.25]

Compares the fresh results in benchmarks/results/*.json against the
COMMITTED ``BENCH_*.json`` baselines at the repo root and fails (exit 1)
on a >25% slowdown of any gated metric. Gated metrics are machine-portable
RATIOS (median-based speedups) rather than absolute seconds: CI runners
and dev boxes differ wildly in absolute fsync/SHA/dispatch throughput, but
the batched-vs-sequential and packed-vs-per-leaf ratios are properties of
the code. Structural invariants — the batched injection path must keep
exactly ONE re-key walk and ONE manifest commit — are checked exactly,
whatever the timings do.

``benchmarks.run --update-baseline`` refreshes the baselines after an
intentional perf change; a plain ``--quick`` run never touches them.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (results file, baseline file, dotted metric path, threshold override) —
# ratio metrics where HIGHER is better; fresh < baseline/threshold fails.
# The multilayer ratios are stable run-to-run (<20% swing at --quick), so
# they gate at the default 1.25. incremental_save's ratio is dominated by
# fsync latency, which swings ~3x between runs on shared machines — its
# wide threshold still catches the real failure mode (the packed pipeline
# losing its advantage and dropping toward 1x) without flaking on noise.
RATIO_GATES = [
    ("incremental_save.json", "BENCH_incremental_save.json", "speedup",
     3.5),
    ("multilayer_inject.json", "BENCH_multilayer_inject.json",
     "k4.speedup_wall", None),
    ("multilayer_inject.json", "BENCH_multilayer_inject.json",
     "k8.speedup_wall", None),
    # delta push vs seed O(image) push: the ratio is dominated by the
    # remote's deep re-verification (SHA throughput — machine-portable);
    # the wide threshold absorbs fsync noise while still catching the
    # delta path losing its advantage (the 1.0 floor below always applies)
    ("push_delta.json", "BENCH_push_delta.json", "k4.speedup_wall", 2.0),
    ("push_delta.json", "BENCH_push_delta.json", "k8.speedup_wall", 2.0),
    # fanout has NO wall-ratio gate on purpose: its wall vs N sequential
    # pushes is fsync-bound (both arms share the same bounded fsync pool),
    # hovering ~1.0-1.3x machine-dependently — a ratio gate would flake.
    # The fan-out claims that are properties of the CODE are exact and
    # gated as INVARIANTS below (one round, source reads == changed blobs
    # == 1/N of sequential, wire budget, sparse-refresh identity);
    # BENCH_fanout.json snapshots the full result for trend reading.
]

# (results file, dotted path, exact expected value)
INVARIANTS = [
    ("multilayer_inject.json", "k1.batched.rekey_walks", 1),
    ("multilayer_inject.json", "k8.batched.rekey_walks", 1),
    ("multilayer_inject.json", "k1.batched.manifest_commits", 1),
    ("multilayer_inject.json", "k8.batched.manifest_commits", 1),
    # the remote deep-verified ONLY the k new-content layers — everything
    # else rode the re-key table or was already held
    ("push_delta.json", "k1.delta.layers_deep_verified", 1),
    ("push_delta.json", "k8.delta.layers_deep_verified", 8),
    # wire bytes within 1.25x of the changed-chunk bytes
    ("push_delta.json", "k1.delta.within_budget", True),
    ("push_delta.json", "k8.delta.within_budget", True),
    # the remote passes a full, independent deep verification post-push
    ("push_delta.json", "k8.delta.remote_deep_verify_clean", True),
    # fan-out: ONE negotiation round for the whole fleet ...
    ("fanout.json", "N2.negotiation_rounds", 1),
    ("fanout.json", "N4.negotiation_rounds", 1),
    # ... the source reads each changed blob exactly once regardless of N
    # (counter-proved against an instrumented store) — N x fewer reads
    # than N sequential pushes ...
    ("fanout.json", "N2.source_reads_equal_changed", True),
    ("fanout.json", "N4.source_reads_equal_changed", True),
    ("fanout.json", "N2.source_read_ratio_vs_sequential", 2),
    ("fanout.json", "N4.source_read_ratio_vs_sequential", 4),
    # ... every replica's wire stays within 1.25x of the changed bytes ...
    ("fanout.json", "N2.within_budget", True),
    ("fanout.json", "N4.within_budget", True),
    # ... and the serving refresh is sparse: Engine.refresh device-puts
    # ONLY the changed leaves, bit-identical to a full reload
    ("fanout.json", "N2.refresh.refresh_only_changed", True),
    ("fanout.json", "N4.refresh.refresh_only_changed", True),
    ("fanout.json", "N2.refresh.refresh_bit_identical", True),
    ("fanout.json", "N4.refresh.refresh_bit_identical", True),
    # relay tier (trainer -> relay -> C edges): the relay reads each
    # changed blob from its parent exactly once (counter-proved) ...
    ("relay.json", "C2.parent_reads_equal_changed", True),
    ("relay.json", "C4.parent_reads_equal_changed", True),
    # ... in-flight re-fan forwards straight from the wire buffer — ZERO
    # local reads, no per-child re-read/re-hash ...
    ("relay.json", "C2.inflight_zero_local_reads", True),
    ("relay.json", "C4.inflight_zero_local_reads", True),
    # ... one negotiation round per tier, parent AND child ...
    ("relay.json", "C2.one_round_per_tier", True),
    ("relay.json", "C4.one_round_per_tier", True),
    # ... stale children are served with ONE local read per blob (C
    # sequential pushes cost exactly C x the reads) ...
    ("relay.json", "C2.stale_one_local_read_per_blob", True),
    ("relay.json", "C4.stale_one_local_read_per_blob", True),
    ("relay.json", "C2.stale_read_ratio_vs_sequential", 2),
    ("relay.json", "C4.stale_read_ratio_vs_sequential", 4),
    # ... every hop's wire stays within 1.25x the changed bytes ...
    ("relay.json", "C2.within_budget", True),
    ("relay.json", "C4.within_budget", True),
    # ... and every edge ends bit-identical to the trainer's save
    ("relay.json", "C2.edges_bit_identical", True),
    ("relay.json", "C4.edges_bit_identical", True),
    # cross-image blob universe (multi-tenant fleet): a fresh fine-tune
    # fanned to base-holding replicas ships only the adapter delta — the
    # sibling image vouches for every backbone blob (counter-proved: zero
    # base-blob reads at the source) ...
    ("multitenant.json", "fleet.negotiation_rounds", 1),
    ("multitenant.json", "fleet.zero_base_blob_transfers", True),
    ("multitenant.json", "fleet.within_budget", True),
    # ... consolidating base + T tenants onto one remote stays within
    # 1.25x (base + sum-of-adapters) in wire AND remote disk ...
    ("multitenant.json", "consolidation.wire_within_budget", True),
    ("multitenant.json", "consolidation.disk_within_budget", True),
    # ... and cross-image gc() removes EXACTLY the unreachable blobs:
    # shared base blobs survive removal of T-1 tenant images
    ("multitenant.json", "gc.exact", True),
    ("multitenant.json", "gc.base_survives", True),
    ("multitenant.json", "gc.survivors_verify_clean", True),
    # self-healing loop: a clean store scrubs quiet (no false positives),
    # scrub finds 100% of injected at-rest flips with exact attribution...
    ("scrub_repair.json", "scrub.clean_store_zero_findings", True),
    ("scrub_repair.json", "detect.detection_100", True),
    # ... anti-entropy repair reads ONLY the damaged blobs at the peer
    # (counter-proved), stays within the 1.25x wire budget, deep-verifies
    # on commit and restores bit-identical payload bytes ...
    ("scrub_repair.json", "repair.reads_only_damaged", True),
    ("scrub_repair.json", "repair.within_budget", True),
    ("scrub_repair.json", "repair.deep_verified", True),
    ("scrub_repair.json", "repair.bit_identical", True),
    # ... and a sliced, cursor-resumed scrub pass unions to the same
    # verdict as one full pass
    ("scrub_repair.json", "sliced.union_equals_full", True),
    # squashed static deltas + passive registry: merging 8 per-commit
    # deltas into one bundle stays within 1.25x of min(sum per-hop, full)
    # — repeated same-chunk overwrites collapse to the final bytes —
    # and replays bit-identically on a scratch store (deep verify +
    # per-chunk byte compare) ...
    ("squash_pull.json", "publish.squash_within_budget", True),
    ("squash_pull.json", "publish.verified_bit_identical", True),
    # ... a follower 8 commits behind converges from plain published
    # files with ZERO negotiation round-trips (DeltaReceiver.negotiate
    # monkeypatch-counted), in ONE applied hop, within 1.25x of the
    # cheapest ADVERTISED chain, deep-verified and bit-identical
    ("squash_pull.json", "follower.negotiation_rounds", 0),
    ("squash_pull.json", "follower.hops_applied", 1),
    ("squash_pull.json", "follower.pulled_within_budget", True),
    ("squash_pull.json", "follower.converged_deep_verified", True),
    ("squash_pull.json", "follower.bit_identical", True),
]


def _load(path: str, problems: list) -> dict | None:
    if not os.path.exists(path):
        problems.append(f"missing {path} — did the benchmark run?")
        return None
    with open(path) as f:
        data = json.load(f)
    if "error" in data:
        problems.append(f"{path}: benchmark errored: {data['error']}")
        return None
    return data


def _dig(data: dict, dotted: str, path: str, problems: list):
    cur = data
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            problems.append(f"{path}: metric {dotted!r} not found")
            return None
        cur = cur[part]
    return cur


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="max tolerated slowdown ratio (1.25 = 25%%)")
    ap.add_argument("--results", default=RESULTS)
    args = ap.parse_args()

    problems: list = []
    for res_name, base_name, metric, override in RATIO_GATES:
        fresh = _load(os.path.join(args.results, res_name), problems)
        base = _load(os.path.join(REPO_ROOT, base_name), problems)
        if fresh is None or base is None:
            continue
        got = _dig(fresh, metric, res_name, problems)
        want = _dig(base, metric, base_name, problems)
        if got is None or want is None:
            continue
        threshold = override or args.threshold
        # absolute sanity floor: whatever the baseline says, a gated
        # speedup at or below 1.0 means the optimized path lost its
        # advantage entirely — always a failure
        floor = max(want / threshold, 1.0)
        verdict = "OK" if got >= floor else "REGRESSION"
        print(f"{verdict:10s} {res_name}:{metric} = {got:.2f} "
              f"(baseline {want:.2f}, floor {floor:.2f})")
        if got < floor:
            problems.append(
                f"{res_name}: {metric} regressed to {got:.2f} "
                f"(baseline {want:.2f}, >{threshold:.2f}x slowdown)")

    for res_name, dotted, expected in INVARIANTS:
        fresh = _load(os.path.join(args.results, res_name), problems)
        if fresh is None:
            continue
        got = _dig(fresh, dotted, res_name, problems)
        if got is None:
            continue
        verdict = "OK" if got == expected else "BROKEN"
        print(f"{verdict:10s} {res_name}:{dotted} = {got} "
              f"(must be {expected})")
        if got != expected:
            problems.append(f"{res_name}: invariant {dotted} = {got}, "
                            f"expected {expected}")

    if problems:
        print("\nFAIL:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("\nbenchmark gate: all metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
