"""The paper's four rebuild scenarios, reconstructed over model-state images.

Each scenario is an image whose layer structure mirrors the paper's
Dockerfile (Fig. 4); "derivations" are real deterministic compute (payload
generation from a seed), not sleeps, so baseline fall-through costs are
honest. Sizes are CPU-scaled but preserve each scenario's *structure*
(which layer is big, what falls through, what must be re-derived).

Scenario 1  "1-line Python, tiny image"
    FROM alpine | COPY main.py (small) | CMD
    edit: one chunk of main.py.
Scenario 2  "1000-line Python + conda deps"
    FROM miniconda | COPY src | WORKDIR | RUN apt (big) | RUN conda (bigger)
    edit: many chunks of src. Docker falls through and re-runs apt+conda;
    injection re-keys them (they do not derive from src).
Scenario 3  "1-line Java, compiled OUTSIDE"
    FROM jdk | COPY app.war (compiled artifact) | EXPOSE | CMD
    edit: recompilation (outside the timed region) changes the artifact
    pervasively; injection still skips the config-layer rebuilds.
Scenario 4  "1000-line Java, compiled INSIDE"
    FROM ubuntu | RUN jdk | COPY pom | RUN deps | COPY src | RUN package | CMD
    edit: many chunks of src. BOTH methods must re-run `package`
    (derives_from src) — the paper's no-win case.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import Instruction, LayerStore, inject_payload_update

KiB, MiB = 1 << 10, 1 << 20


def _gen(seed: int, nbytes: int) -> np.ndarray:
    """Deterministic 'derivation': generating the payload IS the work."""
    n = nbytes // 4
    x = (np.arange(n, dtype=np.uint64) + np.uint64(seed * 2654435761))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    # int32 payloads: random bit patterns viewed as float would contain
    # NaNs, breaking bit-exact equality checks
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)


def _edit_chunks(arr: np.ndarray, n_edits: int, chunk_bytes: int,
                 seed: int = 1) -> np.ndarray:
    """Touch n_edits distinct chunks (the '1 line' / '1000 lines' edit)."""
    out = arr.copy()
    elems_per_chunk = chunk_bytes // 4
    rng = np.random.default_rng(seed)
    chunks = rng.choice(max(arr.size // elems_per_chunk, 1),
                        size=min(n_edits, max(arr.size // elems_per_chunk, 1)),
                        replace=False)
    for c in chunks:
        out[c * elems_per_chunk] += 1
    return out


@dataclass
class Scenario:
    name: str
    instructions: List[Instruction]
    payloads: Dict[str, np.ndarray]            # key -> tensor payload
    edited_key: str
    edited: np.ndarray
    # providers re-run on (baseline fall-through | injection re-derive)
    derive: Dict[str, Callable[[Dict[str, np.ndarray]], np.ndarray]] = \
        field(default_factory=dict)


def scenario_1(chunk_bytes: int) -> Scenario:
    src = _gen(11, 256 * KiB)
    return Scenario(
        name="s1_python_tiny",
        instructions=[
            Instruction("FROM", "python:alpine", "config"),
            Instruction("COPY", "main.py", "content"),
            Instruction("CMD", "python ./main.py", "config"),
        ],
        payloads={"main.py": src},
        edited_key="main.py",
        edited=_edit_chunks(src, 1, chunk_bytes),
    )


def scenario_2(chunk_bytes: int) -> Scenario:
    src = _gen(21, 1 * MiB)
    return Scenario(
        name="s2_python_conda",
        instructions=[
            Instruction("FROM", "continuumio/miniconda3", "config"),
            Instruction("COPY", "src", "content"),
            Instruction("ENV", "WORKDIR /root", "config"),
            Instruction("RUN", "apt_install", "content"),     # independent
            Instruction("RUN", "conda_env", "content"),       # independent
            Instruction("CMD", "python main.py", "config"),
        ],
        payloads={"src": src,
                  "apt_install": _gen(22, 48 * MiB),
                  "conda_env": _gen(23, 96 * MiB)},
        edited_key="src",
        edited=_edit_chunks(src, 1000 // 40, chunk_bytes),  # ~1000 lines
        derive={"apt_install": lambda _: _gen(22, 48 * MiB),
                "conda_env": lambda _: _gen(23, 96 * MiB)},
    )


def _compile(src: np.ndarray, nbytes: int) -> np.ndarray:
    """'Compilation': output depends pervasively on every source byte."""
    h = int(np.abs(src.astype(np.int64)).sum() % (1 << 31))
    return _gen(h ^ 0x5EED, nbytes)


def scenario_3(chunk_bytes: int) -> Scenario:
    src = _gen(31, 64 * KiB)
    war = _compile(src, 4 * MiB)            # compiled OUTSIDE (untimed)
    src2 = _edit_chunks(src, 1, chunk_bytes)
    return Scenario(
        name="s3_java_precompiled",
        instructions=[
            Instruction("FROM", "java:8-jdk-alpine", "config"),
            Instruction("COPY", "app.war", "content"),
            Instruction("ENV", "EXPOSE 8080", "config"),
            Instruction("CMD", "java -jar app.war", "config"),
        ],
        payloads={"app.war": war},
        edited_key="app.war",
        edited=_compile(src2, 4 * MiB),
    )


def scenario_4(chunk_bytes: int) -> Scenario:
    src = _gen(41, 1 * MiB)
    pom = _gen(42, 16 * KiB)
    deps = _gen(43, 40 * MiB)

    def package(payloads: Dict[str, np.ndarray]) -> np.ndarray:
        return _compile(payloads["src"], 16 * MiB)   # compiled INSIDE

    return Scenario(
        name="s4_java_compile_inside",
        instructions=[
            Instruction("FROM", "ubuntu:latest", "config"),
            Instruction("RUN", "apt_jdk", "content"),
            Instruction("COPY", "pom.xml", "content"),
            Instruction("RUN", "mvn_deps", "content",
                        derives_from=["pom.xml"]),
            Instruction("COPY", "src", "content"),
            Instruction("RUN", "mvn_package", "content",
                        derives_from=["src", "mvn_deps"]),
            Instruction("CMD", "java -jar target/app.jar", "config"),
        ],
        payloads={"apt_jdk": _gen(44, 64 * MiB), "pom.xml": pom,
                  "mvn_deps": deps, "src": src,
                  "mvn_package": package({"src": src})},
        edited_key="src",
        edited=_edit_chunks(src, 1000 // 40, chunk_bytes),
        derive={"apt_jdk": lambda _: _gen(44, 64 * MiB),
                "mvn_deps": lambda p: _gen(43, 40 * MiB),
                "mvn_package": package},
    )


def many_leaf_tree(n_leaves: int = 128, leaf_elems: int = 8192,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """A 100+-leaf flat state (think per-block transformer params) for the
    dispatch-bound incremental-save benchmark: per-leaf fingerprinting
    costs one device dispatch + one D2H transfer per leaf, the packed
    pipeline one per checkpoint."""
    rng = np.random.default_rng(seed)
    return {f"l{i:03d}": rng.standard_normal(leaf_elems).astype(np.float32)
            for i in range(n_leaves)}


SCENARIOS = [scenario_1, scenario_2, scenario_3, scenario_4]


def run_scenario(sc: Scenario, store_root: str, trials: int,
                 chunk_bytes: int) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (baseline_seconds, injection_seconds) per trial."""
    base_t, inj_t = [], []
    for trial in range(trials):
        store = LayerStore(f"{store_root}/{sc.name}_{trial}",
                           chunk_bytes=chunk_bytes)
        payloads = dict(sc.payloads)
        # build v1 (untimed)
        prov1 = {k: (lambda v=v: {"data": v}) for k, v in payloads.items()}
        store.build_image("app", "v1", sc.instructions, prov1)

        new_payloads = dict(payloads)
        new_payloads[sc.edited_key] = sc.edited

        def prov_v2(key):
            def f():
                if key in sc.derive and key != sc.edited_key:
                    return {"data": sc.derive[key](new_payloads)}
                return {"data": new_payloads[key]}
            return f

        prov2 = {k: prov_v2(k) for k in new_payloads}

        # --- Docker-faithful baseline: DLC cache + fall-through ---
        t0 = time.perf_counter()
        store.build_image("app", "v2_base", sc.instructions, prov2,
                          parent=("app", "v1"))
        base_t.append(time.perf_counter() - t0)

        # --- the paper's injection method ---
        t0 = time.perf_counter()
        inject_payload_update(
            store, "app", "v1", "v2_inj",
            {sc.edited_key: {"data": new_payloads[sc.edited_key]}},
            providers=prov2)
        inj_t.append(time.perf_counter() - t0)

        # correctness: both paths end at identical content
        a = store.load_image_payload("app", "v2_base")
        b = store.load_image_payload("app", "v2_inj")
        assert set(a) == set(b)
        for k in a:
            assert np.array_equal(a[k], b[k]), (sc.name, k)
        import shutil
        shutil.rmtree(f"{store_root}/{sc.name}_{trial}")
    return np.asarray(base_t), np.asarray(inj_t)
