"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the full results
(means, stds, speedups, Z-test P-values) to benchmarks/results/*.json.

    PYTHONPATH=src python -m benchmarks.run [--trials 30] [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import tempfile
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# Adapted H0 thresholds for the paper's Table II hypothesis test
# (null: speedup <= H0). The paper's absolute H0s (100 / 105000 / 20 / 0.7)
# embed docker-daemon and network-install costs that do not exist here;
# these test the same ORDERING claims on our measured regime.
H0 = {"s1_python_tiny": 1.5, "s2_python_conda": 50.0,
      "s3_java_precompiled": 1.0, "s4_java_compile_inside": 0.7}


def z_test_p(speedups: np.ndarray, h0: float) -> float:
    """P(observed | mu <= h0) one-sided Z (paper eq. 2)."""
    n = len(speedups)
    mu = float(speedups.mean())
    s = float(speedups.std(ddof=1)) or 1e-12
    z = (mu - h0) / (s / math.sqrt(n))
    return 0.5 * math.erfc(z / math.sqrt(2))


def bench_scenarios(trials: int, chunk_bytes: int = 1 << 18) -> dict:
    """Fig. 5 (rebuild time mean±std), Fig. 6 (times faster), Table II."""
    from .scenarios import SCENARIOS, run_scenario
    out = {}
    root = tempfile.mkdtemp(prefix="lc_bench_")
    try:
        for mk in SCENARIOS:
            sc = mk(chunk_bytes)
            base, inj = run_scenario(sc, root, trials, chunk_bytes)
            speed = base / inj
            out[sc.name] = {
                "baseline_mean_s": float(base.mean()),
                "baseline_std_s": float(base.std(ddof=1)),
                "inject_mean_s": float(inj.mean()),
                "inject_std_s": float(inj.std(ddof=1)),
                "speedup_mean": float(speed.mean()),
                "speedup_std": float(speed.std(ddof=1)),
                "speedup_min": float(speed.min()),
                "speedup_max": float(speed.max()),
                "H0": H0[sc.name],
                "P": z_test_p(speed, H0[sc.name]),
                "trials": trials,
            }
            print(f"{sc.name}_baseline,{base.mean() * 1e6:.1f},")
            print(f"{sc.name}_inject,{inj.mean() * 1e6:.1f},"
                  f"speedup={speed.mean():.1f}x P={out[sc.name]['P']:.2e}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_decompose(trials: int) -> dict:
    """Paper §III-A: explicit (docker save tar) vs implicit (in-place)."""
    from repro.core import Instruction, LayerStore
    from .scenarios import _gen
    out = {}
    root = tempfile.mkdtemp(prefix="lc_decomp_")
    try:
        store = LayerStore(os.path.join(root, "s"), chunk_bytes=1 << 18)
        ins = [Instruction("FROM", "base", "config"),
               Instruction("COPY", "payload", "content")]
        payload = {"data": _gen(7, 64 << 20)}
        m, _, _ = store.build_image("app", "v1", ins,
                                    {"payload": lambda: payload})
        explicit, implicit = [], []
        for _ in range(trials):
            t0 = time.perf_counter()
            bundle = store.export_image("app", "v1")      # docker save
            store2 = LayerStore(os.path.join(root, "tmp"),
                                chunk_bytes=1 << 18)
            store2.import_image(bundle)
            lay = store2.read_layer(m.layer_ids[1])
            _ = lay.records[0].chunks[0]
            explicit.append(time.perf_counter() - t0)
            shutil.rmtree(os.path.join(root, "tmp"))
            t0 = time.perf_counter()
            lay = store.open_layer_inplace(m.layer_ids[1])
            _ = lay.records[0].chunks[0]
            implicit.append(time.perf_counter() - t0)
        e, i = np.asarray(explicit), np.asarray(implicit)
        out = {"explicit_mean_s": float(e.mean()),
               "implicit_mean_s": float(i.mean()),
               "speedup": float(e.mean() / i.mean()), "trials": trials}
        print(f"decompose_explicit,{e.mean() * 1e6:.1f},")
        print(f"decompose_implicit,{i.mean() * 1e6:.1f},"
              f"speedup={out['speedup']:.0f}x")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_fallthrough(trials: int) -> dict:
    """Fig. 2 anatomy: rebuild cost vs depth of the edited layer."""
    from repro.core import Instruction, LayerStore, inject_payload_update
    from .scenarios import _edit_chunks, _gen
    out = {}
    root = tempfile.mkdtemp(prefix="lc_ft_")
    n_layers = 6
    try:
        for edit_at in (1, n_layers // 2, n_layers - 1):
            ins = [Instruction("FROM", "base", "config")]
            payloads = {}
            for i in range(n_layers):
                key = f"layer{i}"
                ins.append(Instruction("RUN" if i % 2 else "COPY", key,
                                       "content"))
                payloads[key] = _gen(100 + i, 8 << 20)
            bt, it = [], []
            for tr in range(trials):
                store = LayerStore(os.path.join(root, f"{edit_at}_{tr}"),
                                   chunk_bytes=1 << 18)
                prov = {k: (lambda v=v: {"data": v})
                        for k, v in payloads.items()}
                store.build_image("app", "v1", ins, prov)
                edited = dict(payloads)
                key = f"layer{edit_at}"
                edited[key] = _edit_chunks(payloads[key], 1, 1 << 18)
                prov2 = {k: (lambda v=v: {"data": v})
                         for k, v in edited.items()}
                t0 = time.perf_counter()
                store.build_image("app", "v2", ins, prov2,
                                  parent=("app", "v1"))
                bt.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                inject_payload_update(store, "app", "v1", "v2i",
                                      {key: {"data": edited[key]}})
                it.append(time.perf_counter() - t0)
                shutil.rmtree(os.path.join(root, f"{edit_at}_{tr}"))
            b, i2 = np.asarray(bt), np.asarray(it)
            out[f"edit_at_{edit_at}"] = {
                "baseline_mean_s": float(b.mean()),
                "inject_mean_s": float(i2.mean()),
                "speedup": float((b / i2).mean())}
            print(f"fallthrough_depth{edit_at}_baseline,"
                  f"{b.mean() * 1e6:.1f},")
            print(f"fallthrough_depth{edit_at}_inject,{i2.mean() * 1e6:.1f},"
                  f"speedup={(b / i2).mean():.1f}x")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_ckpt_cadence(trials: int) -> dict:
    """Framework integration: full vs incremental checkpoint save cost for
    an adapter-style update on a real model state (the deployment story)."""
    import jax
    import jax.numpy as jnp
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.configs import get_smoke_config
    from repro.models import init_params
    out = {}
    cfg = get_smoke_config("yi-6b").replace(
        n_layers=4, d_model=256, d_ff=1024, vocab=8192)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = {"step": jnp.int32(0)}
    root = tempfile.mkdtemp(prefix="lc_ckpt_")
    try:
        for mode in ("full", "incremental"):
            times = []
            mgr = CheckpointManager(
                os.path.join(root, mode), cfg.name,
                CheckpointPolicy(incremental=(mode == "incremental"),
                                 async_write=False, chunk_bytes=1 << 18))
            mgr.save(0, params, opt)
            p2 = jax.tree.map(lambda a: a, params)
            for t in range(trials):
                p2 = dict(p2)
                p2["final_norm"] = p2["final_norm"] * (1.0 + 1e-4)
                t0 = time.perf_counter()
                mgr.save(t + 1, p2, opt)
                times.append(time.perf_counter() - t0)
            out[mode] = {"mean_s": float(np.mean(times)),
                         "std_s": float(np.std(times))}
            print(f"ckpt_{mode},{np.mean(times) * 1e6:.1f},")
        out["speedup"] = out["full"]["mean_s"] / out["incremental"]["mean_s"]
        print(f"ckpt_speedup,,{out['speedup']:.1f}x")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_incremental_save(trials: int) -> dict:
    """The fused save pipeline (this repo's perf tentpole): incremental
    checkpoint save on a 100+-leaf state, seed per-leaf fingerprint
    dispatch vs the packed single-dispatch + batch-durability pipeline.
    Also records a bit-identity sweep of the packed fingerprints against
    the numpy oracle. (main() snapshots this to BENCH_incremental_save.json
    at the repo root under --update-baseline.)
    """
    import jax.numpy as jnp
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.core import fingerprint_chunks_ref, fingerprint_tree_packed
    from .scenarios import many_leaf_tree

    n_leaves, leaf_elems, chunk_bytes = 512, 4096, 1 << 14
    # device-resident state, as in real training (the whole point: only
    # fingerprints + changed ranges should cross the host link)
    base_tree = {k: jnp.asarray(v) for k, v in
                 many_leaf_tree(n_leaves=n_leaves,
                                leaf_elems=leaf_elems).items()}
    opt = {"step": jnp.int32(0)}
    out = {"n_leaves": n_leaves, "leaf_bytes": leaf_elems * 4,
           "chunk_bytes": chunk_bytes, "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_incsave_")
    try:
        modes = {
            "perleaf_dispatch": dict(packed_fingerprints=False,
                                     durability="full"),
            "packed_pipeline": dict(packed_fingerprints=True,
                                    durability="batch"),
        }
        for mode, pol in modes.items():
            mgr = CheckpointManager(
                os.path.join(root, mode), "bench",
                CheckpointPolicy(incremental=True, use_fingerprints=True,
                                 async_write=False, chunk_bytes=chunk_bytes,
                                 **pol))
            params = {"blocks": dict(base_tree)}
            mgr.save(0, params, opt)
            # warm the jit caches (packed trace covers the full tree shape)
            params["blocks"] = dict(params["blocks"])
            params["blocks"]["l000"] = params["blocks"]["l000"] + 1e-3
            mgr.save(1, params, opt)
            times = []
            rep = None
            for t in range(trials):
                idx = t % n_leaves
                params["blocks"] = dict(params["blocks"])
                params["blocks"][f"l{idx:03d}"] = \
                    params["blocks"][f"l{idx:03d}"] + 1e-3
                t0 = time.perf_counter()
                rep = mgr.save(t + 2, params, opt)
                times.append(time.perf_counter() - t0)
            times = np.asarray(times)
            out[mode] = {
                "mean_s": float(times.mean()),
                "median_s": float(np.median(times)),
                "std_s": float(times.std(ddof=1)) if trials > 1 else 0.0,
                "min_s": float(times.min()),
                "last_report": {
                    "bytes_d2h": rep.bytes_d2h,
                    "chunks_prefiltered": rep.chunks_prefiltered,
                    "fsyncs": rep.fsyncs,
                    "bytes_serialized": rep.bytes_serialized,
                    "chunks_written": rep.chunks_written,
                },
            }
            print(f"incsave_{mode},{np.median(times) * 1e6:.1f},")
        # median-based headline: robust to fsync-latency outlier trials on
        # shared boxes (mean and min are recorded alongside)
        out["speedup"] = (out["perleaf_dispatch"]["median_s"] /
                          out["packed_pipeline"]["median_s"])
        out["speedup_mean"] = (out["perleaf_dispatch"]["mean_s"] /
                               out["packed_pipeline"]["mean_s"])
        print(f"incsave_speedup,,{out['speedup']:.2f}x")

        # packed fingerprints must be bit-identical to the numpy oracle
        import ml_dtypes
        rng = np.random.default_rng(3)
        sweep = {
            "float32": rng.standard_normal(5000).astype(np.float32),
            "bfloat16": rng.standard_normal(1025).astype(ml_dtypes.bfloat16),
            "int8": rng.integers(-100, 100, 3000).astype(np.int8),
            "bool": rng.standard_normal(1000) > 0,
            "int64": rng.integers(-5, 5, 300).astype(np.int64),
        }
        packed = fingerprint_tree_packed(sweep, 1024)
        out["fingerprint_bit_identical"] = {
            k: bool(np.array_equal(packed[k],
                                   fingerprint_chunks_ref(np.asarray(v),
                                                          1024)))
            for k, v in sweep.items()}
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_multilayer_inject(trials: int) -> dict:
    """The multi-layer transactional unit (this repo's CI tentpole): k
    changed content layers saved as ONE batched injection
    (``inject_image_multi``: one re-key walk + one manifest commit) vs a
    CONSTRUCTED per-layer protocol — one single-layer injection
    transaction per changed layer (k walks, k commits). Both arms run
    under identical batch durability, so the ratio isolates the
    transactional-unit cost (walks, re-keys, commits), not fsync mode.
    Note the baseline is the design alternative a per-layer transactional
    unit would cost, not the seed save path (which already batched diffs
    into one call); edits are one chunk per layer, so wall time IS the
    metadata path. BuildReport counters prove the 1-vs-k walk/commit
    claim.
    """
    from repro.core import (Instruction, LayerStore, diff_image,
                            inject_image_multi)
    from .scenarios import _edit_chunks, _gen

    n_layers, chunk_bytes, layer_bytes = 8, 1 << 16, 2 << 20
    ins = [Instruction("FROM", "base", "config")]
    payloads = {}
    for i in range(n_layers):
        key = f"layer{i}"
        ins.append(Instruction("COPY", key, "content"))
        payloads[key] = _gen(300 + i, layer_bytes)
    ins.append(Instruction("RUN", "setup", "content"))   # independent tail
    payloads["setup"] = _gen(299, layer_bytes)
    ins.append(Instruction("CMD", "serve", "config"))

    def diffs_for(store, tag, keys, edited):
        m, _ = store.read_image("app", tag)
        layers = [store.read_layer(lid) for lid in m.layer_ids]
        return diff_image(layers, {k: {"data": edited[k]} for k in keys})

    out = {"n_layers": n_layers, "chunk_bytes": chunk_bytes,
           "layer_bytes": layer_bytes, "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_multi_")
    try:
        for k in (1, 2, 4, 8):
            keys = [f"layer{i}" for i in range(k)]
            bt, st = [], []
            b_rep = None
            s_counters = {"rekey_walks": 0, "manifest_commits": 0,
                          "layers_rekeyed": 0, "fsyncs": 0}
            for tr in range(trials):
                edited = {key: _edit_chunks(payloads[key], 1, chunk_bytes,
                                            seed=tr + 1) for key in keys}
                store = LayerStore(os.path.join(root, f"b{k}_{tr}"),
                                   chunk_bytes=chunk_bytes)
                prov = {key: (lambda v=v: {"data": v})
                        for key, v in payloads.items()}
                store.build_image("app", "v1", ins, prov)
                diffs = diffs_for(store, "v1", keys, edited)
                t0 = time.perf_counter()
                _, _, b_rep = inject_image_multi(store, "app", "v1", "v2",
                                                 diffs)
                bt.append(time.perf_counter() - t0)
                shutil.rmtree(os.path.join(root, f"b{k}_{tr}"))

                store = LayerStore(os.path.join(root, f"s{k}_{tr}"),
                                   chunk_bytes=chunk_bytes)
                store.build_image("app", "v1", ins, prov)
                tag, elapsed = "v1", 0.0
                for i, key in enumerate(keys):
                    diffs = diffs_for(store, tag, [key], edited)
                    next_tag = f"v2_{i}"
                    t0 = time.perf_counter()
                    _, _, r = inject_image_multi(store, "app", tag,
                                                 next_tag, diffs,
                                                 durability="batch")
                    elapsed += time.perf_counter() - t0
                    for c in s_counters:
                        s_counters[c] += getattr(r, c)
                    tag = next_tag
                st.append(elapsed)
                shutil.rmtree(os.path.join(root, f"s{k}_{tr}"))
            b, s = np.asarray(bt), np.asarray(st)
            out[f"k{k}"] = {
                "batched": {
                    "median_s": float(np.median(b)),
                    "mean_s": float(b.mean()),
                    "min_s": float(b.min()),
                    "rekey_walks": b_rep.rekey_walks,
                    "manifest_commits": b_rep.manifest_commits,
                    "layers_injected": b_rep.layers_injected,
                    "layers_rekeyed": b_rep.layers_rekeyed,
                    "fsyncs": b_rep.fsyncs,
                },
                "sequential": {
                    "median_s": float(np.median(s)),
                    "mean_s": float(s.mean()),
                    "min_s": float(s.min()),
                    **{c: v // trials for c, v in s_counters.items()},
                },
                "speedup_wall": float(np.median(s) / np.median(b)),
            }
            out[f"k{k}"]["metadata_op_ratio"] = (
                (out[f"k{k}"]["sequential"]["layers_rekeyed"]
                 + out[f"k{k}"]["sequential"]["manifest_commits"]) /
                max(out[f"k{k}"]["batched"]["layers_rekeyed"]
                    + out[f"k{k}"]["batched"]["manifest_commits"], 1))
            print(f"multiinject_k{k}_batched,"
                  f"{np.median(b) * 1e6:.1f},walks={b_rep.rekey_walks} "
                  f"commits={b_rep.manifest_commits}")
            print(f"multiinject_k{k}_sequential,{np.median(s) * 1e6:.1f},"
                  f"speedup={out[f'k{k}']['speedup_wall']:.2f}x")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_push_delta(trials: int) -> dict:
    """§III.C redeployment (this repo's delta-replication tentpole): push a
    freshly-injected 512-leaf checkpoint-style image (8 content layers x 64
    leaves) to a remote that already holds the previous version. Seed
    ``push`` walks every layer, rewrites every descriptor and deep-verifies
    the WHOLE image at the destination (O(image)); ``push_delta``
    negotiates the have-set in batched set-difference exchanges, streams
    only the changed chunks over the pipelined transfer and verifies
    incrementally (O(changed bytes)). k = how many of the image's content
    layers changed (the last k — the checkpoint save shape, where every
    param layer is touched; deeper-prefix edits only add re-keyed
    descriptors, still O(#layers) metadata). Gated claims, recorded per k:
    wall speedup, wire amplification (bytes_sent / changed-chunk bytes,
    must stay within 1.25x), the remote deep-verified ONLY the k new
    layers, and an untimed independent ``verify_image(deep=True)`` at the
    remote passes afterwards.
    """
    from repro.core import (Instruction, LayerStore, diff_image,
                            inject_image_multi, push, push_delta)
    from .scenarios import _edit_chunks, _gen

    n_layers, leaves_per_layer, edits_per_layer = 8, 64, 2
    leaf_bytes = chunk_bytes = 128 << 10
    ins = [Instruction("FROM", "base", "config")]
    payloads = {}
    for i in range(n_layers):
        key = f"layer{i}"
        ins.append(Instruction("COPY", key, "content"))
        payloads[key] = {
            f"l{j:03d}": _gen(1000 + i * leaves_per_layer + j, leaf_bytes)
            for j in range(leaves_per_layer)}
    ins.append(Instruction("CMD", "serve", "config"))

    out = {"n_layers": n_layers, "leaves": n_layers * leaves_per_layer,
           "leaf_bytes": leaf_bytes, "chunk_bytes": chunk_bytes,
           "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_push_")
    try:
        for k in (1, 2, 4, 8):
            keys = [f"layer{i}" for i in range(n_layers - k, n_layers)]
            # registry stores: no build-cache fingerprint sidecar (that is
            # a builder concern; a serving registry never runs COPY checks)
            store = LayerStore(os.path.join(root, f"src{k}"),
                               chunk_bytes=chunk_bytes,
                               record_fingerprints=False)
            current = {key: dict(tree) for key, tree in payloads.items()}
            prov = {key: (lambda v=v: v) for key, v in current.items()}
            store.build_image("app", "v1", ins, prov)
            remote_seed = LayerStore(os.path.join(root, f"rs{k}"),
                                     chunk_bytes=chunk_bytes,
                                     record_fingerprints=False)
            remote_delta = LayerStore(os.path.join(root, f"rd{k}"),
                                      chunk_bytes=chunk_bytes,
                                      record_fingerprints=False)
            push(store, remote_seed, "app", "v1")
            push_delta(store, remote_delta, "app", "v1")

            seed_t, delta_t, amp = [], [], []
            s_stats = d_stats = None
            tag, changed_bytes = "v1", 0
            for tr in range(trials):
                # a few fresh chunk edits per changed layer, applied on top
                # of the running state (never reverting an earlier edit)
                for key in keys:
                    current[key] = dict(current[key])
                    for e in range(edits_per_layer):
                        leaf = f"l{(tr * edits_per_layer + e) % leaves_per_layer:03d}"
                        current[key][leaf] = _edit_chunks(
                            current[key][leaf], 1, chunk_bytes, seed=tr + 1)
                m, _ = store.read_image("app", tag)
                layers = [store.read_layer(lid) for lid in m.layer_ids]
                diffs = diff_image(layers,
                                   {key: current[key] for key in keys})
                new_tag = f"t{tr + 1}"
                inject_image_multi(store, "app", tag, new_tag, diffs)
                changed_bytes = sum(len(e.data) for d in diffs.values()
                                    for e in d.edits)
                tag = new_tag

                t0 = time.perf_counter()
                s_stats = push(store, remote_seed, "app", tag)
                seed_t.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                d_stats = push_delta(store, remote_delta, "app", tag)
                delta_t.append(time.perf_counter() - t0)
                amp.append(d_stats.bytes_sent / max(changed_bytes, 1))
            s, d = np.asarray(seed_t), np.asarray(delta_t)
            amp_median = float(np.median(np.asarray(amp)))
            # the acceptance checks, run INDEPENDENTLY of the push path
            remote_clean = remote_delta.verify_image("app", tag,
                                                     deep=True) == []
            out[f"k{k}"] = {
                "changed_bytes": changed_bytes,
                "seed": {
                    "median_s": float(np.median(s)),
                    "mean_s": float(s.mean()),
                    "bytes_sent": s_stats.bytes_sent,
                    "bytes_deduped": s_stats.bytes_deduped,
                    "layers_deep_verified": s_stats.layers_deep_verified,
                },
                "delta": {
                    "median_s": float(np.median(d)),
                    "mean_s": float(d.mean()),
                    "bytes_sent": d_stats.bytes_sent,
                    "bytes_payload": d_stats.bytes_payload,
                    "bytes_meta": d_stats.bytes_meta,
                    "bytes_deduped": d_stats.bytes_deduped,
                    "layers_deep_verified": d_stats.layers_deep_verified,
                    "layers_rekey_verified": d_stats.layers_rekey_verified,
                    "blobs_hashed_remote": d_stats.blobs_hashed_remote,
                    "wire_amplification": amp_median,
                    "within_budget": bool(amp_median <= 1.25),
                    "remote_deep_verify_clean": bool(remote_clean),
                },
                "speedup_wall": float(np.median(s) / np.median(d)),
            }
            print(f"push_k{k}_seed,{np.median(s) * 1e6:.1f},"
                  f"deep={s_stats.layers_deep_verified} "
                  f"bytes={s_stats.bytes_sent}")
            print(f"push_k{k}_delta,{np.median(d) * 1e6:.1f},"
                  f"speedup={out[f'k{k}']['speedup_wall']:.2f}x "
                  f"amp={amp_median:.3f} "
                  f"deep={d_stats.layers_deep_verified}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_fanout(trials: int) -> dict:
    """Fan-out replication + sparse serving refresh (the fleet topology):
    one training source feeding N serving replicas with k=8 changed layers
    of the 512-leaf image (8 content layers x 64 leaves) per save. Gated
    claims per N in {2, 4}: ONE negotiation round; the source reads each
    changed blob from its store exactly once regardless of N —
    counter-proved against an instrumented store, and exactly N x fewer
    reads than N sequential ``push_delta`` calls; per-replica wire stays
    within the 1.25x changed-bytes budget; and at the consumer,
    ``Engine.refresh`` device-puts ONLY the changed leaves after a sparse
    ``changed_tensor_paths`` plan, bit-identical to a full reload.
    """
    from repro.ckpt.manager import flatten_tree, unflatten_tree
    from repro.configs import get_smoke_config
    from repro.core import (Instruction, LayerStore, diff_image,
                            inject_image_multi, push_delta,
                            replicate_fanout)
    from repro.serve import Engine, changed_tensor_paths
    from .scenarios import _edit_chunks, _gen

    n_layers, leaves_per_layer, edits_per_layer = 8, 64, 2
    leaf_bytes = chunk_bytes = 128 << 10
    ins = [Instruction("FROM", "base", "config")]
    payloads = {}
    for i in range(n_layers):
        key = f"layer{i}"
        ins.append(Instruction("COPY", key, "content"))
        payloads[key] = {
            f"L{i}/l{j:03d}": _gen(2000 + i * leaves_per_layer + j,
                                   leaf_bytes)
            for j in range(leaves_per_layer)}
    ins.append(Instruction("CMD", "serve", "config"))
    keys = list(payloads)                     # ALL k=8 content layers move

    out = {"n_layers": n_layers, "leaves": n_layers * leaves_per_layer,
           "leaf_bytes": leaf_bytes, "chunk_bytes": chunk_bytes,
           "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_fan_")
    try:
        for N in (2, 4):
            src = LayerStore(os.path.join(root, f"src{N}"),
                             chunk_bytes=chunk_bytes,
                             record_fingerprints=False)
            current = {key: dict(tree) for key, tree in payloads.items()}
            prov = {key: (lambda v=v: v) for key, v in current.items()}
            src.build_image("app", "v1", ins, prov)
            fan_reps = [LayerStore(os.path.join(root, f"f{N}_{i}"),
                                   chunk_bytes=chunk_bytes,
                                   record_fingerprints=False)
                        for i in range(N)]
            seq_reps = [LayerStore(os.path.join(root, f"q{N}_{i}"),
                                   chunk_bytes=chunk_bytes,
                                   record_fingerprints=False)
                        for i in range(N)]
            replicate_fanout(src, fan_reps, "app", "v1")
            for r in seq_reps:
                push_delta(src, r, "app", "v1")

            fan_t, seq_t, amp, ratio = [], [], [], []
            rounds_ok = reads_ok = True
            changed_blobs = changed_bytes = 0
            tag = "v1"
            for tr in range(trials):
                for key in keys:
                    current[key] = dict(current[key])
                    for e in range(edits_per_layer):
                        leaf = [k for k in current[key]][
                            (tr * edits_per_layer + e) % leaves_per_layer]
                        current[key][leaf] = _edit_chunks(
                            current[key][leaf], 1, chunk_bytes, seed=tr + 1)
                m, _ = src.read_image("app", tag)
                layers = [src.read_layer(lid) for lid in m.layer_ids]
                diffs = diff_image(layers,
                                   {key: current[key] for key in keys})
                new_tag = f"t{tr + 1}"
                inject_image_multi(src, "app", tag, new_tag, diffs)
                changed = {e.new_hash for d in diffs.values()
                           for e in d.edits}
                changed_blobs = len(changed)
                changed_bytes = sum(len(e.data) for d in diffs.values()
                                    for e in d.edits)
                prev_tag, tag = tag, new_tag

                # instrumented source: count ACTUAL blob reads during the
                # fan-out (the exactly-once claim is counter-proved, not
                # taken from FanoutStats). The wrapper runs on hash-pool
                # threads — list.append is the GIL-atomic counter.
                reads = []
                orig_read = src.read_blob
                src.read_blob = lambda h: (reads.append(h), orig_read(h))[1]
                t0 = time.perf_counter()
                fan = replicate_fanout(src, fan_reps, "app", tag)
                fan_t.append(time.perf_counter() - t0)
                del src.read_blob
                assert fan.ok, [r.error for r in fan.replicas]
                rounds_ok &= fan.negotiation_rounds == 1
                reads_ok &= (fan.source_blob_reads == changed_blobs ==
                             len(reads))
                amp.append(max(r.stats.bytes_sent for r in fan.replicas)
                           / max(changed_bytes, 1))

                reads = []
                src.read_blob = lambda h: (reads.append(h), orig_read(h))[1]
                t0 = time.perf_counter()
                for r in seq_reps:
                    push_delta(src, r, "app", tag)
                seq_t.append(time.perf_counter() - t0)
                del src.read_blob
                ratio.append(len(reads) / max(changed_blobs, 1))

            # consumer side: sparse refresh at one replica vs full reload.
            # Engine setup and the previous-revision tree are built OUTSIDE
            # the timed windows — each window times exactly one refresh
            # path: store assembly + unflatten + Engine.refresh.
            rep = fan_reps[0]
            changed_paths = changed_tensor_paths(rep, "app", prev_tag, tag)
            prev_tree = unflatten_tree(rep.load_image_payload("app",
                                                              prev_tag))
            eng = Engine(get_smoke_config("yi-6b"), prev_tree)
            t0 = time.perf_counter()
            full_flat = rep.load_image_payload("app", tag)
            eng.refresh(unflatten_tree(full_flat))
            full_s = time.perf_counter() - t0
            want = {k: v.copy() for k, v in full_flat.items()}
            eng.refresh(prev_tree)                          # rewind
            t0 = time.perf_counter()
            sparse_flat = rep.load_image_payload("app", tag,
                                                 names=changed_paths)
            n_put = eng.refresh(unflatten_tree(sparse_flat), changed_paths)
            partial_s = time.perf_counter() - t0

            live = flatten_tree(eng.params)
            identical = set(live) == set(want) and all(
                np.array_equal(np.asarray(live[p]), want[p]) for p in want)

            # worst replica of the worst trial — the budget is a per-push
            # guarantee, so the gate must see the maximum, not the median
            amp_max = float(np.max(np.asarray(amp)))
            f, s = np.asarray(fan_t), np.asarray(seq_t)
            out[f"N{N}"] = {
                "n_replicas": N,
                "changed_bytes": changed_bytes,
                "changed_blobs": changed_blobs,
                "negotiation_rounds": 1 if rounds_ok else -1,
                "source_reads_equal_changed": bool(reads_ok),
                "source_read_ratio_vs_sequential":
                    float(np.median(np.asarray(ratio))),
                "wire_amplification_max": amp_max,
                "within_budget": bool(amp_max <= 1.25),
                "fanout": {"median_s": float(np.median(f)),
                           "mean_s": float(f.mean())},
                "sequential": {"median_s": float(np.median(s)),
                               "mean_s": float(s.mean())},
                "speedup_wall": float(np.median(s) / np.median(f)),
                "refresh": {
                    "leaves_total": n_layers * leaves_per_layer,
                    "leaves_changed": len(changed_paths),
                    "refresh_leaves_partial": int(n_put),
                    "refresh_only_changed": bool(
                        n_put == len(changed_paths) ==
                        len(sparse_flat) < n_layers * leaves_per_layer),
                    "refresh_bit_identical": bool(identical),
                    "partial_s": partial_s,
                    "full_s": full_s,
                },
            }
            print(f"fanout_N{N},{np.median(f) * 1e6:.1f},"
                  f"rounds=1 reads={changed_blobs} amp={amp_max:.3f}")
            print(f"fanout_N{N}_sequential,{np.median(s) * 1e6:.1f},"
                  f"speedup={out[f'N{N}']['speedup_wall']:.2f}x "
                  f"read_ratio={out[f'N{N}']['source_read_ratio_vs_sequential']:.1f}")
            print(f"fanout_N{N}_refresh,{partial_s * 1e6:.1f},"
                  f"leaves={n_put}/{n_layers * leaves_per_layer} "
                  f"identical={identical}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_relay(trials: int) -> dict:
    """Multi-hop relay replication (the edge-tier topology): one trainer
    feeding a relay that re-fans each k=8-changed-layer save of the
    512-leaf image to C edge children. Gated claims per C in {2, 4}, all
    counter-proved against instrumented stores:

    * the relay reads each changed blob from its PARENT exactly once
      (``FanoutStats.source_blob_reads`` == changed blobs == the
      instrumented count), and — with ``source="inflight"`` — forwards it
      to all C children straight from the wire buffer: ZERO local reads,
      no per-child re-read or re-hash, one negotiation round per tier;
    * when the children lag an already-current relay (the stale arm), each
      owed blob is read from the relay's local store exactly ONCE and
      broadcast — C sequential ``push_delta`` calls cost exactly C x the
      reads;
    * wire per hop (trainer->relay and relay->worst edge) stays within
      1.25x the changed bytes;
    * after the run, every edge's assembled payload is bit-identical to
      the trainer's save and every tier passes an independent deep verify.
    """
    import collections

    from repro.core import (Instruction, LayerStore, RelayNode, diff_image,
                            inject_image_multi, push_delta,
                            replicate_fanout)
    from .scenarios import _edit_chunks, _gen

    n_layers, leaves_per_layer, edits_per_layer = 8, 64, 2
    leaf_bytes = chunk_bytes = 128 << 10
    ins = [Instruction("FROM", "base", "config")]
    payloads = {}
    for i in range(n_layers):
        key = f"layer{i}"
        ins.append(Instruction("COPY", key, "content"))
        payloads[key] = {
            f"L{i}/l{j:03d}": _gen(3000 + i * leaves_per_layer + j,
                                   leaf_bytes)
            for j in range(leaves_per_layer)}
    ins.append(Instruction("CMD", "serve", "config"))
    keys = list(payloads)                     # ALL k=8 content layers move

    out = {"n_layers": n_layers, "leaves": n_layers * leaves_per_layer,
           "leaf_bytes": leaf_bytes, "chunk_bytes": chunk_bytes,
           "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_relay_")

    def instrument(store):
        reads = []
        orig = store.read_blob
        store.read_blob = lambda h: (reads.append(h), orig(h))[1]
        return reads

    try:
        for C in (2, 4):
            src = LayerStore(os.path.join(root, f"src{C}"),
                             chunk_bytes=chunk_bytes,
                             record_fingerprints=False)
            current = {key: dict(tree) for key, tree in payloads.items()}
            prov = {key: (lambda v=v: v) for key, v in current.items()}
            src.build_image("app", "v1", ins, prov)
            # in-flight arm: trainer -> relay -> C edges
            relay = RelayNode(
                LayerStore(os.path.join(root, f"rl{C}"),
                           chunk_bytes=chunk_bytes,
                           record_fingerprints=False),
                children=[LayerStore(os.path.join(root, f"rl{C}e{i}"),
                                     chunk_bytes=chunk_bytes,
                                     record_fingerprints=False)
                          for i in range(C)],
                source="inflight")
            # stale arm: relay store warmed separately, children lag by one
            hot = LayerStore(os.path.join(root, f"hot{C}"),
                             chunk_bytes=chunk_bytes,
                             record_fingerprints=False)
            stale = RelayNode(hot,
                              children=[LayerStore(
                                  os.path.join(root, f"st{C}e{i}"),
                                  chunk_bytes=chunk_bytes,
                                  record_fingerprints=False)
                                  for i in range(C)])
            seq = [LayerStore(os.path.join(root, f"sq{C}e{i}"),
                              chunk_bytes=chunk_bytes,
                              record_fingerprints=False)
                   for i in range(C)]
            assert replicate_fanout(src, [relay], "app", "v1").deep_ok
            push_delta(src, hot, "app", "v1")
            assert replicate_fanout(src, [stale], "app", "v1").deep_ok
            for r in seq:
                push_delta(hot, r, "app", "v1")

            fan_t, seq_t = [], []
            relay_amp, edge_amp = [], []
            parent_reads_ok = inflight_zero_local = True
            stale_once_ok = rounds_ok = True
            stale_ratio = []
            changed_blobs = changed_bytes = 0
            tag = "v1"
            for tr in range(trials):
                for key in keys:
                    current[key] = dict(current[key])
                    for e in range(edits_per_layer):
                        leaf = [k for k in current[key]][
                            (tr * edits_per_layer + e) % leaves_per_layer]
                        current[key][leaf] = _edit_chunks(
                            current[key][leaf], 1, chunk_bytes, seed=tr + 1)
                m, _ = src.read_image("app", tag)
                layers = [src.read_layer(lid) for lid in m.layer_ids]
                diffs = diff_image(layers,
                                   {key: current[key] for key in keys})
                new_tag = f"t{tr + 1}"
                inject_image_multi(src, "app", tag, new_tag, diffs)
                changed_blobs = len({e.new_hash for d in diffs.values()
                                     for e in d.edits})
                changed_bytes = sum(len(e.data) for d in diffs.values()
                                    for e in d.edits)
                tag = new_tag

                # ---- in-flight: one parent read pass, zero local reads
                p_reads = instrument(src)
                l_reads = instrument(relay.store)
                t0 = time.perf_counter()
                fan = replicate_fanout(src, [relay], "app", tag)
                fan_t.append(time.perf_counter() - t0)
                del src.read_blob, relay.store.read_blob
                assert fan.deep_ok, [r.error for r in fan.replicas]
                parent_reads_ok &= (fan.source_blob_reads == changed_blobs
                                    == len(p_reads))
                inflight_zero_local &= (len(l_reads) == 0
                                        and relay.local_blob_reads == 0
                                        and relay.inflight_blobs
                                        == changed_blobs)
                rounds_ok &= (fan.negotiation_rounds == 1
                              and relay.fan.negotiation_rounds == 1)
                relay_amp.append(fan.replicas[0].stats.bytes_sent
                                 / max(changed_bytes, 1))
                edge_amp.append(max(r.stats.bytes_sent
                                    for r in relay.fan.replicas)
                                / max(changed_bytes, 1))

                # ---- stale children: ONE local read per blob for C edges,
                # vs C sequential pushes costing exactly C x the reads
                push_delta(src, hot, "app", tag)
                h_reads = instrument(hot)
                fan2 = replicate_fanout(src, [stale], "app", tag)
                del hot.read_blob
                assert fan2.deep_ok, [r.error for r in fan2.replicas]
                counts = collections.Counter(h_reads)
                stale_once_ok &= (stale.local_blob_reads == changed_blobs
                                  == len(counts)
                                  and max(counts.values()) == 1)
                h_reads = instrument(hot)
                t0 = time.perf_counter()
                for r in seq:
                    push_delta(hot, r, "app", tag)
                seq_t.append(time.perf_counter() - t0)
                del hot.read_blob
                stale_ratio.append(len(h_reads) / max(changed_blobs, 1))

            # edge payloads bit-identical to the trainer's final save
            want = src.load_image_payload("app", tag)
            identical = True
            for child in relay.children + stale.children:
                got = child.store.load_image_payload("app", tag)
                identical &= set(got) == set(want) and all(
                    np.array_equal(got[p], want[p]) for p in want)
                identical &= child.store.verify_image("app", tag,
                                                      deep=True) == []

            f, s = np.asarray(fan_t), np.asarray(seq_t)
            out[f"C{C}"] = {
                "n_children": C,
                "changed_bytes": changed_bytes,
                "changed_blobs": changed_blobs,
                "parent_reads_equal_changed": bool(parent_reads_ok),
                "inflight_zero_local_reads": bool(inflight_zero_local),
                "one_round_per_tier": bool(rounds_ok),
                "stale_one_local_read_per_blob": bool(stale_once_ok),
                "stale_read_ratio_vs_sequential":
                    float(np.median(np.asarray(stale_ratio))),
                # the budget is a per-push guarantee: gate the worst trial
                "relay_hop_amp_max": float(np.max(np.asarray(relay_amp))),
                "edge_hop_amp_max": float(np.max(np.asarray(edge_amp))),
                "within_budget": bool(
                    max(np.max(np.asarray(relay_amp)),
                        np.max(np.asarray(edge_amp))) <= 1.25),
                "edges_bit_identical": bool(identical),
                "relay_fanout": {"median_s": float(np.median(f)),
                                 "mean_s": float(f.mean())},
                "sequential_refan": {"median_s": float(np.median(s)),
                                     "mean_s": float(s.mean())},
            }
            print(f"relay_C{C},{np.median(f) * 1e6:.1f},"
                  f"parent_reads={changed_blobs} local=0 "
                  f"amp={out[f'C{C}']['edge_hop_amp_max']:.3f}")
            print(f"relay_C{C}_stale,{np.median(s) * 1e6:.1f},"
                  f"local_reads={changed_blobs} "
                  f"ratio={out[f'C{C}']['stale_read_ratio_vs_sequential']:.1f}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_multitenant(trials: int) -> dict:
    """Cross-image blob universe (the fleet-of-fine-tunes topology): T
    tenant images forked from ONE base (shared backbone layers, per-tenant
    adapter), stored and replicated in a single cross-image namespace.
    Gated claims, all counter-proved against instrumented stores:

    * pushing a fresh tenant to a replica that holds only the BASE image
      ships only the adapter delta — ZERO base/backbone blobs are read at
      the source or cross the wire (the sibling image vouches for them);
    * consolidating base + T tenants onto one remote costs, in wire AND
      in remote disk, at most 1.25x (base bytes + sum of adapter bytes) —
      tenants dedup against the base and against each other;
    * cross-image ``gc()`` is exact: removing T-1 tenant images sweeps
      precisely their exclusive adapter blobs, and every blob the base
      (or the surviving tenant) reaches stays on disk.
    """
    from repro.core import Instruction, LayerStore, push_delta, \
        replicate_fanout
    from .scenarios import _gen

    T, R = 4, 2                         # tenants, base-holding replicas
    n_backbone, leaves_per_layer = 4, 8
    leaf_bytes = chunk_bytes = 128 << 10
    adapter_leaves = 2

    ins = [Instruction("FROM", "base", "config")]
    backbone = {}
    for i in range(n_backbone):
        key = f"backbone{i}"
        ins.append(Instruction("COPY", key, "content"))
        backbone[key] = {f"B{i}/l{j:03d}": _gen(7000 + i * 64 + j,
                                                leaf_bytes)
                         for j in range(leaves_per_layer)}
    ins.append(Instruction("COPY", "adapter", "content"))
    ins.append(Instruction("CMD", "serve", "config"))

    def adapter_payload(t):
        return {f"A/l{j}": _gen(9000 + t * 16 + j, leaf_bytes)
                for j in range(adapter_leaves)}

    def image_chunks(store, name, tag="v1"):
        m, _ = store.read_image(name, tag)
        return {h for lid in m.layer_ids
                for rec in store.read_layer(lid).records
                for h in rec.chunks}

    def blob_bytes(store, chunks):
        return sum(len(store.read_blob(h)) for h in chunks)

    def disk_blob_bytes(store):
        total = 0
        for dirpath, _, files in os.walk(os.path.join(store.root, "blobs")):
            total += sum(os.path.getsize(os.path.join(dirpath, f))
                         for f in files)
        return total

    out = {"tenants": T, "replicas": R, "backbone_layers": n_backbone,
           "leaf_bytes": leaf_bytes, "chunk_bytes": chunk_bytes,
           "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_mt_")
    try:
        src = LayerStore(os.path.join(root, "src"),
                         chunk_bytes=chunk_bytes,
                         record_fingerprints=False)
        prov = {key: (lambda v=v: v) for key, v in backbone.items()}
        base_ad = adapter_payload(0)
        prov["adapter"] = lambda: base_ad
        src.build_image("base", "v1", ins, prov)
        base_chunks = image_chunks(src, "base")
        base_bytes = blob_bytes(src, base_chunks)

        tenant_chunks = {}
        for t in range(1, T + 1):
            ad = adapter_payload(t)
            tprov = dict(prov)
            tprov["adapter"] = lambda v=ad: v
            _, _, rep = src.build_image(f"tenant{t}", "v1", ins, tprov,
                                        parent=("base", "v1"))
            assert rep.layers_cached >= n_backbone + 1   # FROM + backbone
            tenant_chunks[t] = image_chunks(src, f"tenant{t}")
        adapter_chunks = {t: tenant_chunks[t] - base_chunks
                          for t in tenant_chunks}
        adapter_bytes = {t: blob_bytes(src, adapter_chunks[t])
                         for t in adapter_chunks}

        # -- fleet arm: per-tenant fan-out to R base-holding replicas ----
        replicas = [LayerStore(os.path.join(root, f"r{i}"),
                               chunk_bytes=chunk_bytes,
                               record_fingerprints=False)
                    for i in range(R)]
        for r in replicas:
            push_delta(src, r, "base", "v1")

        fan_t, amp = [], []
        rounds_ok = zero_base = True
        orig_read = src.read_blob
        for t in range(1, T + 1):
            reads = []
            src.read_blob = lambda h: (reads.append(h), orig_read(h))[1]
            t0 = time.perf_counter()
            fan = replicate_fanout(src, replicas, f"tenant{t}", "v1")
            fan_t.append(time.perf_counter() - t0)
            del src.read_blob
            assert fan.ok, [r.error for r in fan.replicas]
            rounds_ok &= fan.negotiation_rounds == 1
            # the counter-proof: NOT ONE backbone blob was even read
            zero_base &= not (set(reads) & base_chunks)
            zero_base &= set(reads) == adapter_chunks[t]
            amp.append(max(r.stats.bytes_sent for r in fan.replicas)
                       / max(adapter_bytes[t], 1))
        amp_max = float(np.max(np.asarray(amp)))
        out["fleet"] = {
            "negotiation_rounds": 1 if rounds_ok else -1,
            "zero_base_blob_transfers": bool(zero_base),
            "wire_amplification_max": amp_max,
            "within_budget": bool(amp_max <= 1.25),
            "per_tenant_median_s": float(np.median(np.asarray(fan_t))),
            "adapter_bytes": adapter_bytes[1],
            "base_bytes": base_bytes,
        }
        print(f"multitenant_fleet,{np.median(np.asarray(fan_t)) * 1e6:.1f},"
              f"T={T} zero_base={zero_base} amp={amp_max:.3f}")

        # -- consolidation arm: base + T tenants onto ONE empty remote ---
        remote = LayerStore(os.path.join(root, "remote"),
                            chunk_bytes=chunk_bytes,
                            record_fingerprints=False)
        wire = push_delta(src, remote, "base", "v1").bytes_sent
        for t in range(1, T + 1):
            wire += push_delta(src, remote, f"tenant{t}", "v1").bytes_sent
        budget = base_bytes + sum(adapter_bytes.values())
        disk = disk_blob_bytes(remote)
        out["consolidation"] = {
            "wire_total": wire,
            "disk_blob_bytes": disk,
            "budget_bytes": budget,
            "wire_amplification": wire / budget,
            "disk_amplification": disk / budget,
            "wire_within_budget": bool(wire <= 1.25 * budget),
            "disk_within_budget": bool(disk <= 1.25 * budget),
        }
        print(f"multitenant_consolidation,wire={wire},"
              f"amp={wire / budget:.3f} disk_amp={disk / budget:.3f}")

        # -- gc arm: drop T-1 tenants at the remote, sweep exactly -------
        survivors = base_chunks | tenant_chunks[T]
        expected = len(set().union(*(adapter_chunks[t]
                                     for t in range(1, T))) - survivors)
        for t in range(1, T):
            assert remote.remove_image(f"tenant{t}", "v1")
        stats = remote.gc()
        base_ok = all(remote.has_blob(h) for h in survivors)
        out["gc"] = {
            "blobs_swept": stats["blobs_swept"],
            "blobs_expected": expected,
            "exact": bool(stats["blobs_swept"] == expected),
            "base_survives": bool(base_ok),
            "survivors_verify_clean": bool(
                remote.verify_image("base", "v1", deep=True) == [] and
                remote.verify_image(f"tenant{T}", "v1", deep=True) == []),
        }
        print(f"multitenant_gc,swept={stats['blobs_swept']},"
              f"expected={expected} base_survives={base_ok}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_scrub_repair(trials: int) -> dict:
    """Self-healing loop (scrub -> anti-entropy repair), gated claims all
    counter-proved against instrumented stores:

    * a clean store scrubs to ZERO findings (no false positives);
    * scrub detects 100% of injected at-rest bit flips, attributed to the
      exact flipped blob set;
    * repair from a pristine peer reads ONLY the damaged blobs at the
      source (read-counter proof), stays within the 1.25x wire budget,
      deep-verifies on commit, and restores bit-identical payload bytes;
    * a sliced/resumable scrub pass unions to the same verdict as one
      full pass.
    """
    from repro.core import Instruction, LayerStore, push, repair_image
    from repro.ft.faults import inject_bitrot
    from repro.ft.scrub import load_cursor
    from .scenarios import _gen

    n_layers, leaves_per_layer, flips = 3, 4, 3
    leaf_bytes = chunk_bytes = 64 << 10

    ins = [Instruction("FROM", "base", "config")]
    payloads = {}
    for i in range(n_layers):
        key = f"layer{i}"
        ins.append(Instruction("COPY", key, "content"))
        payloads[key] = {
            f"l{j:03d}": _gen(4000 + i * leaves_per_layer + j, leaf_bytes)
            for j in range(leaves_per_layer)}
    ins.append(Instruction("CMD", "serve", "config"))

    out = {"n_layers": n_layers, "leaves": n_layers * leaves_per_layer,
           "leaf_bytes": leaf_bytes, "chunk_bytes": chunk_bytes,
           "flips": flips, "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_scrub_")
    try:
        src = LayerStore(os.path.join(root, "src"),
                         chunk_bytes=chunk_bytes,
                         record_fingerprints=False)
        prov = {key: (lambda v=v: v) for key, v in payloads.items()}
        src.build_image("app", "v1", ins, prov)
        m, _ = src.read_image("app", "v1")
        chunks = {h for lid in m.layer_ids
                  for rec in src.read_layer(lid).records
                  for h in rec.chunks}
        pristine = {h: src.read_blob(h) for h in chunks}
        store_bytes = sum(len(b) for b in pristine.values())

        clean_t, detect_t, repair_t = [], [], []
        clean_zero = detect_100 = reads_only = True
        within = deep_ok = bit_ok = union_ok = True
        amps, slice_counts = [], []
        for tr in range(trials):
            victim = LayerStore(os.path.join(root, f"v{tr}"),
                                chunk_bytes=chunk_bytes,
                                record_fingerprints=False)
            push(src, victim, "app", "v1")

            # -- clean arm: a healthy store must scrub quiet ------------
            t0 = time.perf_counter()
            rep = victim.scrub(reset=True)
            clean_t.append(time.perf_counter() - t0)
            clean_zero &= bool(rep.clean)

            # -- detection arm: every injected flip found, none extra --
            want = {h for h, _ in inject_bitrot(
                victim.root, seed=100 + tr, count=flips,
                candidates=sorted(chunks))}
            assert len(want) == flips
            t0 = time.perf_counter()
            rep = victim.scrub(reset=True)
            detect_t.append(time.perf_counter() - t0)
            detect_100 &= bool(set(rep.corrupt_blob_hashes) == want)

            # -- repair arm: counter-proof that ONLY damaged bytes move
            reads = []
            orig = src.read_blob
            src.read_blob = lambda h: (reads.append(h), orig(h))[1]
            try:
                t0 = time.perf_counter()
                rr = repair_image(victim, "app", "v1", peers=[src],
                                  scrub_report=rep)
                repair_t.append(time.perf_counter() - t0)
            finally:
                src.read_blob = orig
            reads_only &= bool(set(reads) == want)
            amps.append(rr.wire_amplification)
            within &= bool(rr.wire_amplification <= 1.25)
            deep_ok &= bool(rr.verified_clean)
            victim.purge_quarantine()
            bit_ok &= all(victim.read_blob(h) == pristine[h]
                          for h in chunks)

            # -- sliced arm: resumable slices union to the full verdict
            want2 = {h for h, _ in inject_bitrot(
                victim.root, seed=200 + tr, count=flips,
                candidates=sorted(chunks))}
            merged = victim.scrub(max_items=4, reset=True)
            slices = 1
            while load_cursor(victim.root) != 0:
                merged.merge(victim.scrub(max_items=4))
                slices += 1
            slice_counts.append(slices)
            union_ok &= bool(set(merged.corrupt_blob_hashes) == want2)

        c, d, r = (np.asarray(clean_t), np.asarray(detect_t),
                   np.asarray(repair_t))
        amp_median = float(np.median(np.asarray(amps)))
        out["scrub"] = {
            "median_s": float(np.median(c)),
            "mean_s": float(c.mean()),
            "MBps": store_bytes / max(float(np.median(c)), 1e-12) / 1e6,
            "clean_store_zero_findings": bool(clean_zero),
        }
        out["detect"] = {
            "median_s": float(np.median(d)),
            "detection_100": bool(detect_100),
        }
        out["repair"] = {
            "median_s": float(np.median(r)),
            "reads_only_damaged": bool(reads_only),
            "wire_amplification": amp_median,
            "within_budget": bool(within),
            "deep_verified": bool(deep_ok),
            "bit_identical": bool(bit_ok),
        }
        out["sliced"] = {
            "median_slices": float(np.median(np.asarray(slice_counts))),
            "union_equals_full": bool(union_ok),
        }
        print(f"scrub_clean,{np.median(c) * 1e6:.1f},"
              f"zero_findings={clean_zero} "
              f"MBps={out['scrub']['MBps']:.1f}")
        print(f"scrub_detect,{np.median(d) * 1e6:.1f},"
              f"detection_100={detect_100}")
        print(f"scrub_repair,{np.median(r) * 1e6:.1f},"
              f"amp={amp_median:.3f} reads_only_damaged={reads_only} "
              f"bit_identical={bit_ok}")
        print(f"scrub_sliced,,slices={np.median(np.asarray(slice_counts))}"
              f" union_equals_full={union_ok}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_squash_pull(trials: int) -> dict:
    """Squashed static delta chains through the passive bundle registry,
    gated claims counter-proved:

    * squashing k=8 per-commit deltas into ONE static bundle stays within
      1.25x of min(sum of per-hop bundles, full bundle) — repeated
      overwrites of the same chunk collapse to the final bytes;
    * the squashed bundle is BIT-identical to replaying the chain
      (``verify_squashed_bundle``: scratch-store apply + deep verify +
      per-chunk byte compare);
    * a follower 8 commits behind converges from plain published files
      with ZERO negotiation round-trips (``DeltaReceiver.negotiate``
      monkeypatch-counted) pulling within 1.25x of the cheapest
      advertised chain, deep-verified and bit-identical at the end.
    """
    from repro.core import (Instruction, LayerStore, PassiveRegistry,
                            inject_payload_update, plan_bundle_chain,
                            push, squash_deltas, verify_squashed_bundle)
    from repro.core.registry import DeltaReceiver
    from repro.serve.engine import CheckpointFollower

    steps, chunk_bytes = 9, 4096
    hops = steps - 1

    def tag(s: int) -> str:
        return f"step-{s:08d}"

    out = {"steps": steps, "hops": hops, "chunk_bytes": chunk_bytes,
           "trials": trials}
    root = tempfile.mkdtemp(prefix="lc_squash_")
    try:
        rng = np.random.default_rng(42)
        src = LayerStore(os.path.join(root, "src"),
                         chunk_bytes=chunk_bytes,
                         record_fingerprints=False)
        state = {"params/w": rng.standard_normal(16384).astype(np.float32),
                 "opt/m": rng.standard_normal(16384).astype(np.float32),
                 "opt/__step__": np.asarray([1], np.int32)}
        ins = [Instruction("FROM", "arch", "config"),
               Instruction("COPY", "state", "content")]
        src.build_image("ckpt", tag(1), ins, {"state": lambda: state})
        # every commit rewrites the SAME hot head of params/w (the bytes a
        # squash collapses) plus a per-step slice of opt/m (the bytes it
        # must keep) — the checkpoint-stream shape the paper's injection
        # path produces
        for s in range(2, steps + 1):
            state = {k: v.copy() for k, v in state.items()}
            state["params/w"][:1024] = rng.standard_normal(1024)
            state["opt/m"][(s - 1) * 1024:s * 1024] += 1.0
            state["opt/__step__"][0] = s
            inject_payload_update(src, "ckpt", tag(s - 1), tag(s),
                                  {"state": state})

        # trainer-cadence publishing: one incremental publish per commit
        # (per-hop chain accumulates in the index), then the lagging-edge
        # advertisement — ONE squashed bundle spanning all 8 hops
        reg = PassiveRegistry(os.path.join(root, "registry"))
        for s in range(2, steps + 1):
            reg.publish_image(src, "ckpt", tag(s), from_tags=[tag(s - 1)])
        index = reg.publish_image(src, "ckpt", tag(steps),
                                  from_tags=[tag(1)])
        ent = {(e.from_tag, e.to_tag): e for e in index.entries}
        per_hop_bytes = sum(ent[(tag(s - 1), tag(s))].size
                            for s in range(2, steps + 1))
        squashed_bytes = ent[(tag(1), tag(steps))].size
        full_bytes = ent[("", tag(steps))].size
        budget = min(per_hop_bytes, full_bytes) * 1.25

        # cheapest ADVERTISED chain for a follower holding only step 1 —
        # the yardstick the pull must stay within 1.25x of
        chain = plan_bundle_chain(index, [tag(1)])
        cheapest = sum(e.size for e in chain)

        m9, _ = src.read_image("ckpt", tag(steps))
        chunks9 = {h for lid in m9.layer_ids
                   for rec in src.read_layer(lid).records
                   for h in rec.chunks}

        squash_t, poll_t = [], []
        neg_rounds = 0
        verified = conv_ok = bit_ok = pulled_ok = True
        hops_applied = pull_bytes = planned_bytes = 0
        for tr in range(trials):
            t0 = time.perf_counter()
            bundle = squash_deltas(src, "ckpt", tag(1), tag(steps))
            squash_t.append(time.perf_counter() - t0)
            if tr == 0:
                verified = verify_squashed_bundle(src, bundle) == []

            # passive-only follower (remote=None): plain files are the
            # ONLY channel, so any negotiate() call would be a lie —
            # counter-proved by counting them
            local = LayerStore(os.path.join(root, f"f{tr}"),
                               chunk_bytes=chunk_bytes,
                               record_fingerprints=False)
            push(src, local, "ckpt", tag(1))
            follower = CheckpointFollower(None, local, image="ckpt",
                                          keep=steps + 2, registry=reg)
            calls = []
            orig = DeltaReceiver.negotiate
            DeltaReceiver.negotiate = \
                lambda self, *a, **k: (calls.append(1),
                                       orig(self, *a, **k))[1]
            try:
                t0 = time.perf_counter()
                upd = follower.poll()
                poll_t.append(time.perf_counter() - t0)
            finally:
                DeltaReceiver.negotiate = orig
            neg_rounds += len(calls)
            assert upd is not None and upd.step == steps
            plan = follower.last_plan
            hops_applied = plan.hops
            pull_bytes = plan.bytes_pulled
            planned_bytes = plan.planned_bytes
            pulled_ok &= bool(pull_bytes <= cheapest * 1.25)
            conv_ok &= local.verify_image("ckpt", tag(steps),
                                          deep=True) == []
            bit_ok &= all(local.read_blob(h) == src.read_blob(h)
                          for h in chunks9)

        sq, pl = np.asarray(squash_t), np.asarray(poll_t)
        out["publish"] = {
            "per_hop_bytes": int(per_hop_bytes),
            "squashed_bytes": int(squashed_bytes),
            "full_bytes": int(full_bytes),
            "collapse_ratio": per_hop_bytes / max(squashed_bytes, 1),
            "budget_ratio": squashed_bytes
            / max(min(per_hop_bytes, full_bytes), 1),
            "squash_within_budget": bool(squashed_bytes <= budget),
            "verified_bit_identical": bool(verified),
            "squash_median_s": float(np.median(sq)),
        }
        out["follower"] = {
            "lag_commits": hops,
            "negotiation_rounds": int(neg_rounds),
            "hops_applied": int(hops_applied),
            "pull_bytes": int(pull_bytes),
            "planned_bytes": int(planned_bytes),
            "cheapest_advertised_bytes": int(cheapest),
            "pull_ratio": pull_bytes / max(cheapest, 1),
            "pulled_within_budget": bool(pulled_ok),
            "converged_deep_verified": bool(conv_ok),
            "bit_identical": bool(bit_ok),
            "poll_median_s": float(np.median(pl)),
        }
        print(f"squash_publish,{np.median(sq) * 1e6:.1f},"
              f"squashed={squashed_bytes}B per_hop={per_hop_bytes}B "
              f"full={full_bytes}B within={out['publish']['squash_within_budget']}"
              f" collapse={out['publish']['collapse_ratio']:.2f}x")
        print(f"squash_verify,,bit_identical={verified}")
        print(f"passive_pull,{np.median(pl) * 1e6:.1f},"
              f"hops={hops_applied} negotiations={neg_rounds} "
              f"pulled={pull_bytes}B cheapest={cheapest}B "
              f"deep_verified={conv_ok} bit_identical={bit_ok}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def bench_fingerprint(trials: int) -> dict:
    """Change-detector throughput: host SHA-256 vs on-device fingerprint
    (jnp path; the Pallas kernel is the TPU-target implementation)."""
    import hashlib

    import jax.numpy as jnp
    from repro.core import fingerprint_chunks
    arr = np.random.default_rng(0).standard_normal(32 << 18)  # 32 MiB f32
    jarr = jnp.asarray(arr, jnp.float32)
    fingerprint_chunks(jarr).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(trials):
        fingerprint_chunks(jarr).block_until_ready()
    fp_t = (time.perf_counter() - t0) / trials
    data = arr.tobytes()
    t0 = time.perf_counter()
    for _ in range(trials):
        hashlib.sha256(data).hexdigest()
    sha_t = (time.perf_counter() - t0) / trials
    nbytes = len(data)
    out = {"sha256_GBps": nbytes / sha_t / 1e9,
           "fingerprint_GBps": nbytes / fp_t / 1e9,
           "speedup": sha_t / fp_t}
    print(f"chg_detect_sha256,{sha_t * 1e6:.1f},"
          f"{out['sha256_GBps']:.2f}GB/s")
    print(f"chg_detect_fingerprint,{fp_t * 1e6:.1f},"
          f"{out['fingerprint_GBps']:.2f}GB/s")
    return out


def bench_roofline() -> dict:
    """Collect the dry-run artifacts into the §Roofline table."""
    from .roofline_table import build_table
    table = build_table()
    for row in table["rows"][:5]:
        print(f"roofline_{row['arch']}_{row['shape']},,"
              f"dom={row['dominant']} frac={row['roofline_fraction']:.3f}")
    print(f"roofline_cells,,{len(table['rows'])}")
    return table


# Benches with a committed repo-root baseline snapshot: the CI regression
# gate (benchmarks/check_regression.py) compares fresh results/<name>.json
# against BENCH_<name>.json. Baselines are only (re)written under
# --update-baseline so a CI --quick run never clobbers the committed one.
BASELINES = {
    "incremental_save": "BENCH_incremental_save.json",
    "multilayer_inject": "BENCH_multilayer_inject.json",
    "push_delta": "BENCH_push_delta.json",
    "fanout": "BENCH_fanout.json",
    "relay": "BENCH_relay.json",
    "multitenant": "BENCH_multitenant.json",
    "scrub_repair": "BENCH_scrub_repair.json",
    "squash_pull": "BENCH_squash_pull.json",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=30)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--update-baseline", action="store_true",
                    help="snapshot BENCH_*.json baselines at the repo root")
    args = ap.parse_args()
    trials = 5 if args.quick else args.trials

    os.makedirs(RESULTS, exist_ok=True)
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    results = {}
    benches = {
        "scenarios": lambda: bench_scenarios(trials),
        "decompose": lambda: bench_decompose(max(trials // 3, 3)),
        "fallthrough": lambda: bench_fallthrough(max(trials // 3, 3)),
        "ckpt_cadence": lambda: bench_ckpt_cadence(trials),
        "incremental_save": lambda: bench_incremental_save(trials),
        "multilayer_inject": lambda: bench_multilayer_inject(trials),
        "push_delta": lambda: bench_push_delta(max(trials // 3, 5)),
        "fanout": lambda: bench_fanout(max(trials // 3, 5)),
        "relay": lambda: bench_relay(max(trials // 3, 5)),
        "multitenant": lambda: bench_multitenant(max(trials // 3, 3)),
        "scrub_repair": lambda: bench_scrub_repair(max(trials // 3, 3)),
        "squash_pull": lambda: bench_squash_pull(max(trials // 3, 3)),
        "fingerprint": lambda: bench_fingerprint(trials),
        "roofline": bench_roofline,
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            results[name] = fn()
        except Exception as e:
            import traceback
            traceback.print_exc()
            results[name] = {"error": str(e)}
        with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
            json.dump(results[name], f, indent=1, default=str)
        if args.update_baseline and name in BASELINES and \
                "error" not in results[name]:
            with open(os.path.join(repo_root, BASELINES[name]), "w") as f:
                json.dump(results[name], f, indent=1, default=str)


if __name__ == "__main__":
    main()
