"""CI docs gate: keep README.md / docs/*.md honest.

    python -m benchmarks.check_docs

Three classes of drift, all exact and dependency-free:

1. **Dangling internal links** — every relative markdown link target in
   README.md and docs/*.md must exist on disk (anchors and external URLs
   are skipped).
2. **Bench-table ↔ baseline drift** — every ``BENCH_*.json`` a doc names
   must exist at the repo root AND be registered in
   ``benchmarks.run.BASELINES``; conversely, every registered baseline
   must be documented in the README bench table. Adding a bench without
   a doc row (or deleting one without pruning the docs) fails CI.
3. **Stale headline numbers** — the README's quantitative claims rest on
   committed baseline metrics; ``CLAIMS`` pins each claim to the metric
   range it paraphrases. When an intentional perf change moves a
   baseline outside the range (``--update-baseline``), this gate forces
   the prose to be updated in the same PR instead of drifting quietly.
4. **Analyzer rule-table drift** — the "Protocol invariants" table in
   docs/ARCHITECTURE.md must list exactly the rule ids registered in
   ``repro.analysis.RULES``: adding a rule without documenting its
   contract (or documenting a rule that no longer exists) fails CI.

Runs in the lint job (no benchmark execution needed — it reads only the
COMMITTED baselines and the docs).
"""
from __future__ import annotations

import json
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md"]

# (claim shown on failure, baseline file, dotted metric path, lo, hi) —
# the committed metric must satisfy lo <= value <= hi (None = unbounded).
# Ranges are what the README PROSE promises, not the CI perf gate: wider
# than check_regression's thresholds, tight enough that the text would
# read as wrong outside them.
CLAIMS = [
    ("README: incremental save '~2.5-3.5x wall'",
     "BENCH_incremental_save.json", "speedup", 2.0, 4.5),
    ("README: multilayer inject 'k=8: ~3.4-3.9x wall'",
     "BENCH_multilayer_inject.json", "k8.speedup_wall", 3.0, 4.5),
    ("README: delta push 'k=8: ~4x wall'",
     "BENCH_push_delta.json", "k8.speedup_wall", 3.0, 5.5),
    ("README: delta push 'wire bytes ~= 1.08x changed bytes'",
     "BENCH_push_delta.json", "k8.delta.wire_amplification", 1.0, 1.15),
    ("README: fanout 'per-replica wire <= 1.25x changed bytes'",
     "BENCH_fanout.json", "N4.within_budget", True, True),
    ("README: fanout 'Engine.refresh puts 16/512 leaves'",
     "BENCH_fanout.json", "N4.refresh.refresh_only_changed", True, True),
    ("README/ARCHITECTURE: multitenant 'ZERO base-blob transfers'",
     "BENCH_multitenant.json", "fleet.zero_base_blob_transfers",
     True, True),
    ("README/ARCHITECTURE: multitenant 'wire and disk <= 1.25x'",
     "BENCH_multitenant.json", "consolidation.wire_within_budget",
     True, True),
    ("README/ARCHITECTURE: multitenant 'gc sweeps EXACTLY'",
     "BENCH_multitenant.json", "gc.exact", True, True),
    ("README/ARCHITECTURE: scrub 'detects 100% of injected flips'",
     "BENCH_scrub_repair.json", "detect.detection_100", True, True),
    ("README/ARCHITECTURE: repair 'pulls ONLY the damaged bytes'",
     "BENCH_scrub_repair.json", "repair.reads_only_damaged", True, True),
    ("README/ARCHITECTURE: repair 'restores bit-identical state'",
     "BENCH_scrub_repair.json", "repair.bit_identical", True, True),
    ("README: repair 'wire <= 1.25x damaged bytes'",
     "BENCH_scrub_repair.json", "repair.within_budget", True, True),
    ("README: scrub 'sliced pass unions to the full verdict'",
     "BENCH_scrub_repair.json", "sliced.union_equals_full", True, True),
    ("README/ARCHITECTURE: squash 'one bundle <= 1.25x min(per-hop sum, "
     "full)'",
     "BENCH_squash_pull.json", "publish.squash_within_budget", True, True),
    ("README/ARCHITECTURE: squash 'replays bit-identically'",
     "BENCH_squash_pull.json", "publish.verified_bit_identical",
     True, True),
    ("README/ARCHITECTURE: passive pull 'ZERO negotiation round-trips'",
     "BENCH_squash_pull.json", "follower.negotiation_rounds", 0, 0),
    ("README: passive pull '<= 1.25x the cheapest advertised chain'",
     "BENCH_squash_pull.json", "follower.pulled_within_budget",
     True, True),
    ("README: passive pull 'converges deep-verified, bit-identical'",
     "BENCH_squash_pull.json", "follower.bit_identical", True, True),
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_BENCH = re.compile(r"\bBENCH_[A-Za-z0-9_]+\.json\b")


def _doc_paths() -> list[str]:
    docs = [os.path.join(REPO_ROOT, f) for f in DOC_FILES]
    docs_dir = os.path.join(REPO_ROOT, "docs")
    if os.path.isdir(docs_dir):
        docs.extend(os.path.join(docs_dir, f)
                    for f in sorted(os.listdir(docs_dir))
                    if f.endswith(".md"))
    return [d for d in docs if os.path.exists(d)]


def _dig(data, dotted: str):
    for part in dotted.split("."):
        if not isinstance(data, dict) or part not in data:
            return None
        data = data[part]
    return data


def check_links(problems: list) -> None:
    for doc in _doc_paths():
        rel_doc = os.path.relpath(doc, REPO_ROOT)
        with open(doc) as f:
            text = f.read()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = os.path.normpath(
                os.path.join(os.path.dirname(doc),
                             target.split("#", 1)[0]))
            if not os.path.exists(path):
                problems.append(f"{rel_doc}: dangling link -> {target}")


def check_bench_tables(problems: list) -> None:
    sys.path.insert(0, REPO_ROOT)
    from benchmarks.run import BASELINES
    registered = set(BASELINES.values())

    mentioned: set = set()
    for doc in _doc_paths():
        rel_doc = os.path.relpath(doc, REPO_ROOT)
        with open(doc) as f:
            names = set(_BENCH.findall(f.read()))
        mentioned |= names
        for name in sorted(names):
            if not os.path.exists(os.path.join(REPO_ROOT, name)):
                problems.append(f"{rel_doc}: references {name} but it is "
                                "not committed at the repo root")
            if name not in registered:
                problems.append(f"{rel_doc}: references {name} but "
                                "benchmarks.run.BASELINES does not "
                                "produce it")
    for name in sorted(registered - mentioned):
        problems.append(f"BASELINES produces {name} but no doc mentions "
                        "it — add a bench-table row")


def check_claims(problems: list) -> None:
    for claim, base_name, dotted, lo, hi in CLAIMS:
        path = os.path.join(REPO_ROOT, base_name)
        if not os.path.exists(path):
            problems.append(f"{claim}: baseline {base_name} missing")
            continue
        with open(path) as f:
            got = _dig(json.load(f), dotted)
        if got is None:
            problems.append(f"{claim}: metric {dotted!r} not found in "
                            f"{base_name}")
        elif isinstance(lo, bool):
            if got is not lo:
                problems.append(f"{claim}: {base_name}:{dotted} = {got}, "
                                f"doc claims {lo}")
            else:
                print(f"OK         {base_name}:{dotted} = {got}")
        elif not (lo <= got <= hi):
            problems.append(f"{claim}: {base_name}:{dotted} = {got:.3f} "
                            f"outside documented range [{lo}, {hi}] — "
                            "update the prose with the baseline")
        else:
            print(f"OK         {base_name}:{dotted} = {round(got, 3)}")


def check_analyzer_rule_table(problems: list) -> None:
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    from repro.analysis import RULES
    arch = os.path.join(REPO_ROOT, "docs", "ARCHITECTURE.md")
    with open(arch) as f:
        text = f.read()
    # rule-table rows look like "| `R1` | contract... | bug... |"
    documented = set(re.findall(r"^\|\s*`(R\d+)`\s*\|", text, re.M))
    for rid in sorted(set(RULES) - documented):
        problems.append(f"docs/ARCHITECTURE.md: analyzer rule {rid} "
                        "is registered but missing from the Protocol "
                        "invariants table")
    for rid in sorted(documented - set(RULES)):
        problems.append(f"docs/ARCHITECTURE.md: Protocol invariants "
                        f"table documents {rid} but repro.analysis.RULES "
                        "does not register it")
    for rid in sorted(documented & set(RULES)):
        print(f"OK         ARCHITECTURE.md rule table documents {rid}")


def main() -> int:
    problems: list = []
    check_links(problems)
    check_bench_tables(problems)
    check_claims(problems)
    check_analyzer_rule_table(problems)
    if problems:
        print("\nFAIL:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("\ndocs gate: all links resolve, bench tables match baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
