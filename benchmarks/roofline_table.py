"""Assemble the §Roofline table from dry-run artifacts (benchmarks/results/
dryrun/*.json) — per (arch x shape x mesh): three terms, dominant
bottleneck, useful-flops ratio, roofline fraction."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def build_table(tag: str = "") -> Dict:
    rows: List[dict] = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            rows.append({"arch": d.get("arch"), "shape": d.get("shape"),
                         "mesh": d.get("mesh"), "ok": False,
                         "error": d.get("error")})
            continue
        t = d["terms"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "recipe": d.get("recipe", ""),
            "ok": True,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "useful_flops_ratio": t["useful_flops_ratio"],
            "roofline_fraction": t["roofline_fraction"],
            "flops_per_device": d["flops_per_device"],
            "bytes_per_device": d["bytes_per_device"],
            "coll_total_bytes": d["coll_bytes"].get("total", 0.0),
            "model_flops": d["model_flops"],
            "arg_gb": d.get("arg_bytes", 0) / 1e9,
            "temp_gb": d.get("temp_bytes", 0) / 1e9,
            "compile_s": d.get("compile_seconds", 0.0),
        })
    return {"rows": rows}


def markdown(tag: str = "", mesh: str = "pod") -> str:
    table = build_table(tag)
    lines = [
        "| arch | shape | recipe | compute_s | memory_s | coll_s | dominant "
        "| useful | roofline | mem/dev (arg+temp GB) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in table["rows"]:
        if not r.get("ok") or r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['recipe']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['arg_gb']:.1f}+{r['temp_gb']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(markdown(*(sys.argv[1:])))
